//! `Session`: the generic driver for any train/distill step artifact.
//!
//! A session owns the parameter set, the AdamW state, and the global step
//! counter, and knows how to assemble an artifact's input vector from them
//! plus a named `Batch`. The same driver runs task training, distillation,
//! finetuning, and LoRA (any graph whose manifest follows the
//! params/m/v/step/lr/wd/batch naming convention from aot.py). It drives
//! artifacts through the backend-agnostic `Executable` handle: compiled
//! model graphs via the `pjrt` feature, or — hermetically, with nothing on
//! disk — the reference backend's builtin `ref_lm` training graphs
//! (`runtime/ref_lm.rs`: native forward + backward + AdamW), which is what
//! keeps the train-loop integration test, the conversion pipeline, and the
//! train bench running in CI without `make artifacts`.

use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{ArtifactRegistry, Executable, ExecOptions, ParamStore, Tensor};

/// Typed error for a step whose `loss` output came back NaN/Inf
/// (divergence, poisoned batch, bad checkpoint). Surfaced instead of
/// silently entering `Session::losses`, where it would corrupt every
/// trailing mean and loss-decrease gate downstream. The session's
/// params/opt state HAS already absorbed the bad update when this is
/// returned — recovery policy (skip + rollback) belongs to the guarded
/// layer, [`Session::run_guarded`].
#[derive(Debug, Clone, PartialEq)]
pub struct NonFiniteLoss {
    /// Step index the failing update ran at (pre-increment).
    pub step: i32,
    /// Step artifact that produced it.
    pub artifact: String,
    pub loss: f32,
}

impl std::fmt::Display for NonFiniteLoss {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "step artifact {:?} produced non-finite loss {} at step {}",
            self.artifact, self.loss, self.step
        )
    }
}

impl std::error::Error for NonFiniteLoss {}

/// What [`Session::run_guarded`] did: how many steps landed, which batch
/// cursors were skipped as poisonous, and the checkpoint/rollback count.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GuardReport {
    /// optimization steps that completed
    pub steps: usize,
    /// global batch cursors whose step produced a non-finite loss
    pub skipped: Vec<usize>,
    /// rollbacks to the last checkpoint
    pub rollbacks: usize,
    /// checkpoints written (the entry checkpoint included)
    pub checkpoints: usize,
    /// loss of the last completed step (NaN if `steps == 0`)
    pub final_loss: f32,
}

/// Leaf name carrying the global step counter inside a session
/// checkpoint (disjoint from `params/`, `m/`, `v/` by construction).
pub const CKPT_STEP_KEY: &str = "ckpt/step";

/// Named batch tensors, matched to manifest slots by name.
#[derive(Debug, Clone, Default)]
pub struct Batch {
    pub slots: Vec<(String, Tensor)>,
}

impl Batch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with(mut self, name: impl Into<String>, t: Tensor) -> Self {
        self.slots.push((name.into(), t));
        self
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.slots.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }
}

/// One optimization session over a `<tag>_train_step`-style artifact.
pub struct Session {
    step_exe: Rc<Executable>,
    /// All `params/...` (and for LoRA graphs `lora/...` + frozen `base/...`)
    /// leaves, by name.
    pub params: ParamStore,
    /// AdamW moments `m/...`, `v/...`.
    pub opt: ParamStore,
    pub step: i32,
    pub losses: Vec<f32>,
}

impl Session {
    /// `init`, after applying execution tuning to the registry's backend.
    /// NOTE: options are registry-wide (shared by every executable the
    /// registry serves, including engines/sessions created earlier) — a
    /// convenience for processes with one dominant workload, not
    /// per-session isolation. Training steps are throughput-bound, so
    /// reference-backend sessions usually want every core
    /// (`ExecOptions::default()` auto-threads).
    pub fn init_with_exec_options(
        reg: &ArtifactRegistry,
        tag: &str,
        seed: u32,
        opts: ExecOptions,
    ) -> Result<Session> {
        reg.set_exec_options(opts);
        Session::init(reg, tag, seed)
    }

    /// Initialize from a `<tag>_init` graph with the given seed.
    pub fn init(reg: &ArtifactRegistry, tag: &str, seed: u32) -> Result<Session> {
        let init = reg.get(&format!("{tag}_init"))?;
        let outs = init.run(&[Tensor::scalar_u32(seed)])?;
        let params = ParamStore::from_outputs(&init.manifest.outputs, outs);
        Session::from_params(reg, tag, params)
    }

    /// Resume from an existing parameter store (e.g. after conversion).
    pub fn from_params(reg: &ArtifactRegistry, tag: &str, params: ParamStore) -> Result<Session> {
        let step_exe = reg.get(&format!("{tag}_train_step"))?;
        Ok(Session::over(step_exe, params))
    }

    /// Use an explicit step artifact (e.g. `<tag>_distill_step`).
    pub fn with_step_artifact(
        reg: &ArtifactRegistry,
        step_name: &str,
        params: ParamStore,
    ) -> Result<Session> {
        Ok(Session::over(reg.get(step_name)?, params))
    }

    fn over(step_exe: Rc<Executable>, params: ParamStore) -> Session {
        // zero optimizer state for every m/ v/ input declared by the graph
        let mut opt = ParamStore::new();
        for slot in &step_exe.manifest.inputs {
            if slot.name.starts_with("m/") || slot.name.starts_with("v/") {
                opt.insert(slot.name.clone(), Tensor::zeros(slot.dtype, &slot.shape));
            }
        }
        Session { step_exe, params, opt, step: 0, losses: Vec::new() }
    }

    /// Run one optimization step; returns the loss.
    ///
    /// Inputs are assembled *by reference* (`run_refs`): parameters and
    /// optimizer moments are fed back every step, and cloning them per
    /// step dominated the small-model hot path (§Perf L3).
    pub fn train_step(&mut self, lr: f32, wd: f32, batch: &Batch) -> Result<f32> {
        let step_before = self.step;
        let step_t = Tensor::scalar_i32(self.step);
        let lr_t = Tensor::scalar_f32(lr);
        let wd_t = Tensor::scalar_f32(wd);
        let exe = self.step_exe.clone();
        let man = &exe.manifest;
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(man.inputs.len());
        for slot in &man.inputs {
            let t: &Tensor = match slot.name.as_str() {
                "step" => &step_t,
                "lr" => &lr_t,
                "wd" => &wd_t,
                name => {
                    if let Ok(p) = self.params.get(name) {
                        p
                    } else if let Ok(o) = self.opt.get(name) {
                        o
                    } else if let Some(b) = batch.get(name) {
                        b
                    } else {
                        return Err(anyhow!(
                            "step {}: no source for input {:?}",
                            man.name,
                            slot.name
                        ));
                    }
                }
            };
            inputs.push(t);
        }
        let outs = exe.run_refs(&inputs)?;
        let mut loss = None;
        for (slot, t) in man.outputs.iter().zip(outs) {
            match slot.name.as_str() {
                "step" => self.step = t.item_i32()?,
                "loss" => loss = Some(t.item_f32()?),
                name if name.starts_with("m/") || name.starts_with("v/") => {
                    self.opt.insert(name.to_string(), t)
                }
                name => self.params.insert(name.to_string(), t),
            }
        }
        // A step graph that declares no `loss` output is not a train step
        // (silently recording NaN would poison every downstream trailing
        // mean and loss-decrease gate) — fail loudly, naming the artifact.
        let loss = loss.ok_or_else(|| {
            anyhow!("step artifact {:?} declares no `loss` output", man.name)
        })?;
        // Non-finite loss is a typed error, not a recorded data point.
        // NOTE: params/opt/step were already scattered above — the bad
        // update is in the session. Rollback policy lives in
        // `run_guarded`; bare callers should treat the session as
        // tainted (restore a checkpoint or discard it).
        if !loss.is_finite() {
            return Err(anyhow::Error::new(NonFiniteLoss {
                step: step_before,
                artifact: man.name.clone(),
                loss,
            }));
        }
        self.losses.push(loss);
        Ok(loss)
    }

    /// Train `steps` steps pulling batches from `next_batch`.
    pub fn run(
        &mut self,
        steps: usize,
        lr: impl Fn(usize) -> f32,
        wd: f32,
        mut next_batch: impl FnMut(usize) -> Batch,
    ) -> Result<f32> {
        let mut last = f32::NAN;
        for i in 0..steps {
            let b = next_batch(i);
            last = self.train_step(lr(i), wd, &b)?;
        }
        Ok(last)
    }

    /// Mean loss over the trailing `n` recorded steps.
    pub fn trailing_loss(&self, n: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let k = n.min(self.losses.len());
        self.losses[self.losses.len() - k..].iter().sum::<f32>() / k as f32
    }

    // -- crash safety (DESIGN.md §11) -----------------------------------

    /// Atomically checkpoint the full optimization state — every param
    /// leaf, the AdamW `m/`/`v/` moments, and the step counter (under
    /// [`CKPT_STEP_KEY`]) — in the existing `ParamStore` binary format
    /// via `save_atomic`: a crash mid-write leaves the previous
    /// checkpoint intact. The loss history is telemetry, not
    /// optimization state, and is deliberately not checkpointed.
    pub fn checkpoint(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut all = self.params.clone();
        for (name, t) in &self.opt.tensors {
            all.insert(name.clone(), t.clone());
        }
        all.insert(CKPT_STEP_KEY, Tensor::scalar_i32(self.step));
        all.save_atomic(path)
    }

    /// Roll this session's params/opt/step back to a [`checkpoint`]
    /// (`losses` is untouched — truncate it yourself if replaying).
    ///
    /// [`checkpoint`]: Session::checkpoint
    pub fn restore(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let (params, opt, step) = split_checkpoint(ParamStore::load(path)?)?;
        self.params = params;
        self.opt = opt;
        self.step = step;
        Ok(())
    }

    /// Rebuild a session from a checkpoint in a fresh process (the
    /// kill-and-resume path): same params, moments, and step counter, so
    /// step k+1 is bit-identical to the uninterrupted run's. The loss
    /// history starts empty.
    pub fn resume(
        reg: &ArtifactRegistry,
        step_name: &str,
        path: impl AsRef<Path>,
    ) -> Result<Session> {
        let (params, opt, step) = split_checkpoint(ParamStore::load(path)?)?;
        let step_exe = reg.get(step_name)?;
        Ok(Session { step_exe, params, opt, step, losses: Vec::new() })
    }

    /// `run` with the skip-and-rollback guardrail: checkpoint on entry
    /// and every `ckpt_every` completed steps; when a step raises
    /// [`NonFiniteLoss`], mark its global batch cursor poisonous, roll
    /// the session back to the last checkpoint, and replay — skipping
    /// every known-bad cursor. `lr` is indexed by completed-step count,
    /// `next_batch` by the global cursor (so a replay feeds the same
    /// data stream minus the poison). Fails if more cursors are skipped
    /// than steps requested (the data is hopeless, not unlucky).
    pub fn run_guarded(
        &mut self,
        steps: usize,
        lr: impl Fn(usize) -> f32,
        wd: f32,
        mut next_batch: impl FnMut(usize) -> Batch,
        ckpt_path: impl AsRef<Path>,
        ckpt_every: usize,
    ) -> Result<GuardReport> {
        assert!(ckpt_every > 0, "ckpt_every must be positive");
        let path = ckpt_path.as_ref();
        let mut report = GuardReport { final_loss: f32::NAN, ..GuardReport::default() };
        self.checkpoint(path)?;
        report.checkpoints = 1;
        // (completed steps, batch cursor, losses len) at the last checkpoint
        let mut ckpt = (0usize, 0usize, self.losses.len());
        let mut done = 0usize;
        let mut cursor = 0usize;
        while done < steps {
            if report.skipped.len() > steps {
                bail!(
                    "run_guarded: skipped {} batches for {} requested steps — every \
                     replay hits new non-finite losses, giving up",
                    report.skipped.len(),
                    steps
                );
            }
            if report.skipped.contains(&cursor) {
                cursor += 1;
                continue;
            }
            let b = next_batch(cursor);
            match self.train_step(lr(done), wd, &b) {
                Ok(loss) => {
                    report.final_loss = loss;
                    done += 1;
                    cursor += 1;
                    if done % ckpt_every == 0 {
                        self.checkpoint(path)?;
                        report.checkpoints += 1;
                        ckpt = (done, cursor, self.losses.len());
                    }
                }
                Err(e) if e.downcast_ref::<NonFiniteLoss>().is_some() => {
                    report.skipped.push(cursor);
                    report.rollbacks += 1;
                    self.restore(path)?;
                    self.losses.truncate(ckpt.2);
                    done = ckpt.0;
                    cursor = ckpt.1;
                }
                Err(e) => return Err(e),
            }
        }
        report.steps = done;
        Ok(report)
    }
}

/// Split a checkpoint store back into (params, opt moments, step).
fn split_checkpoint(all: ParamStore) -> Result<(ParamStore, ParamStore, i32)> {
    let mut params = ParamStore::new();
    let mut opt = ParamStore::new();
    let mut step = None;
    for (name, t) in all.tensors {
        if name == CKPT_STEP_KEY {
            step = Some(t.item_i32()?);
        } else if name.starts_with("m/") || name.starts_with("v/") {
            opt.insert(name, t);
        } else {
            params.insert(name, t);
        }
    }
    let step = step.ok_or_else(|| {
        anyhow!("checkpoint missing {CKPT_STEP_KEY:?} leaf — not a session checkpoint?")
    })?;
    Ok((params, opt, step))
}

/// Deterministic, learnable batch for the builtin `ref_lm` training
/// graphs: cyclic next-token sequences over a 64-token sub-vocabulary at
/// the graphs' fixed (batch, seq) geometry, one rotation per batch row.
/// `offset` rotates all rows (pass an rng draw to de-correlate steps);
/// `tokens_only` matches the distill graph's batch (no labels). Shared by
/// the integration tests, the train bench, and the `refconv` experiment
/// so they all exercise the same data distribution.
pub fn ref_lm_demo_batch(offset: usize, tokens_only: bool) -> Batch {
    let (b, n) = (crate::runtime::ref_lm::TRAIN_BATCH, crate::runtime::ref_lm::TRAIN_SEQ);
    let mut tokens = Vec::with_capacity(b * n);
    let mut targets = Vec::with_capacity(b * n);
    for bi in 0..b {
        for t in 0..n {
            tokens.push((((t + bi * 5 + offset) * 7) % 64) as i32);
            targets.push((((t + 1 + bi * 5 + offset) * 7) % 64) as i32);
        }
    }
    let mut batch = Batch::new().with("tokens", Tensor::from_i32(tokens, &[b, n]));
    if !tokens_only {
        batch = batch
            .with("targets", Tensor::from_i32(targets, &[b, n]))
            .with("loss_mask", Tensor::from_f32(vec![1.0; b * n], &[b, n]));
    }
    batch
}

/// Run a non-training artifact (eval / logits / stats) against a parameter
/// store plus a batch, matching inputs by name.
pub fn run_with_params(
    reg: &ArtifactRegistry,
    name: &str,
    params: &ParamStore,
    batch: &Batch,
) -> Result<Vec<Tensor>> {
    let exe = reg.get(name)?;
    let man = &exe.manifest;
    let mut inputs: Vec<&Tensor> = Vec::with_capacity(man.inputs.len());
    for slot in &man.inputs {
        let t = if let Ok(p) = params.get(&slot.name) {
            p
        } else if let Some(b) = batch.get(&slot.name) {
            b
        } else {
            return Err(anyhow!("{name}: no source for input {:?}", slot.name));
        };
        inputs.push(t);
    }
    exe.run_refs(&inputs)
}

/// Evaluate `<tag>_eval` over `n_batches`, returning (mean loss, mean
/// metric). `n_batches` must be positive — a 0-batch evaluation would
/// return (NaN, NaN) from the 0/0 division and silently poison reports.
pub fn evaluate(
    reg: &ArtifactRegistry,
    tag: &str,
    params: &ParamStore,
    n_batches: usize,
    mut next_batch: impl FnMut(usize) -> Batch,
) -> Result<(f32, f32)> {
    if n_batches == 0 {
        return Err(anyhow!("evaluate({tag:?}): n_batches must be > 0"));
    }
    let mut loss_sum = 0.0;
    let mut metric_sum = 0.0;
    for i in 0..n_batches {
        let b = next_batch(i);
        let outs = run_with_params(reg, &format!("{tag}_eval"), params, &b)?;
        loss_sum += outs[0].item_f32()?;
        metric_sum += outs[1].item_f32()?;
    }
    Ok((loss_sum / n_batches as f32, metric_sum / n_batches as f32))
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;
    use std::path::Path;

    use super::*;
    use crate::runtime::backend::{Backend, Executable as BackendExecutable};
    use crate::runtime::{DType, Manifest, Slot};

    /// A backend whose only artifact is a "train step" that echoes its
    /// parameter and declares no `loss` output — the misdeclared-graph
    /// case `train_step` must reject instead of recording NaN.
    struct NoLossBackend;

    struct NoLossExe;

    impl BackendExecutable for NoLossExe {
        fn execute(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
            Ok(vec![inputs[0].clone(), Tensor::scalar_i32(1)])
        }
    }

    fn no_loss_manifest() -> Manifest {
        let w = |name: &str| Slot { name: name.to_string(), shape: vec![2], dtype: DType::F32 };
        let scalar = |name: &str, dtype| Slot { name: name.to_string(), shape: vec![], dtype };
        Manifest {
            name: "noloss_train_step".to_string(),
            inputs: vec![
                w("params/w"),
                scalar("step", DType::I32),
                scalar("lr", DType::F32),
                scalar("wd", DType::F32),
            ],
            outputs: vec![w("params/w"), scalar("step", DType::I32)],
            meta: BTreeMap::new(),
        }
    }

    impl Backend for NoLossBackend {
        fn name(&self) -> &'static str {
            "no-loss-test"
        }

        fn load(&self, _dir: &Path, _manifest: &Manifest) -> Result<Box<dyn BackendExecutable>> {
            Ok(Box::new(NoLossExe))
        }

        fn builtin_manifests(&self) -> Vec<Manifest> {
            vec![no_loss_manifest()]
        }
    }

    #[test]
    fn train_step_errors_when_graph_declares_no_loss() {
        let reg =
            ArtifactRegistry::with_backend("/nonexistent-dir", Box::new(NoLossBackend)).unwrap();
        let mut params = ParamStore::new();
        params.insert("params/w", Tensor::from_f32(vec![1.0, 2.0], &[2]));
        let mut s = Session::with_step_artifact(&reg, "noloss_train_step", params).unwrap();
        let err = s.train_step(1e-3, 0.0, &Batch::new()).unwrap_err();
        assert!(
            err.to_string().contains("noloss_train_step")
                && err.to_string().contains("no `loss` output"),
            "{err:#}"
        );
        assert!(s.losses.is_empty(), "a failed step must not record a loss");
    }

    #[test]
    fn train_step_surfaces_non_finite_loss_as_typed_error() {
        let reg = ArtifactRegistry::open("/nonexistent/artifacts-dir").unwrap();
        reg.set_exec_options(ExecOptions::serial());
        let mut s = Session::init(&reg, "ref_lm", 3).unwrap();
        let mut batch = ref_lm_demo_batch(0, false);
        // poison the loss mask: the masked mean loss is NaN
        for (name, t) in batch.slots.iter_mut() {
            if name == "loss_mask" {
                t.as_f32_mut().unwrap()[0] = f32::NAN;
            }
        }
        let err = s.train_step(1e-3, 0.0, &batch).unwrap_err();
        let nf = err.downcast_ref::<NonFiniteLoss>().expect("typed NonFiniteLoss");
        assert_eq!(nf.step, 0, "reports the step the failing update ran at");
        assert_eq!(nf.artifact, "ref_lm_train_step");
        assert!(!nf.loss.is_finite());
        assert!(s.losses.is_empty(), "the poisoned loss must not be recorded");
    }

    #[test]
    fn evaluate_rejects_zero_batches() {
        let reg = ArtifactRegistry::open("/nonexistent/artifacts-dir").unwrap();
        let params = crate::runtime::ref_lm_demo_params();
        let err = evaluate(&reg, "ref_lm", &params, 0, |_| Batch::new()).unwrap_err();
        assert!(err.to_string().contains("n_batches"), "{err:#}");
    }
}
