//! Tier-1 soundness gate (DESIGN.md §12): the same static checks that
//! back the `contract_check` binary, run inside `cargo test` so they can
//! never rot out of the default CI path.
//!
//! Three layers, all hermetic (no graph execution, no threads beyond the
//! model checker's own bookkeeping, no filesystem):
//!
//! 1. every builtin tag × graph family's manifest matches the
//!    independently derived contract;
//! 2. the mutation self-test proves the checker *detects* each seeded
//!    corruption class (a checker that accepts everything also passes
//!    layer 1);
//! 3. the pool schedule model explores its bounded interleavings clean,
//!    and each seeded protocol bug is caught.

use hedgehog::analysis::{contract, schedule};

#[test]
fn builtin_contracts_hold_statically() {
    let report = contract::check_builtins();
    assert!(report.tags >= 3, "expected all builtin tags, saw {}", report.tags);
    assert!(
        report.artifacts >= report.tags * 5,
        "expected init/decode/eval + train graphs per tag, saw {} artifacts",
        report.artifacts
    );
    assert!(
        report.ok(),
        "builtin contract violations:\n{}",
        report
            .violations
            .iter()
            .map(|v| format!("  {v}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn mutation_self_test_proves_detection_power() {
    let detected = contract::mutation_self_test().expect("self-test must pass on a sound checker");
    assert!(
        detected.len() >= 10,
        "self-test shrank to {} corruption cases — keep every class covered",
        detected.len()
    );
}

#[test]
fn pool_schedules_are_clean_and_seeded_bugs_are_caught() {
    for (name, spec) in schedule::clean_specs() {
        let report = schedule::explore(&spec);
        assert!(report.complete, "{name}: state cap truncated the clean sweep");
        assert!(
            report.violation.is_none(),
            "{name}: clean protocol violated: {:?}",
            report.violation
        );
    }
    for (name, spec, expected) in schedule::seeded_bug_specs() {
        let report = schedule::explore(&spec);
        let v = report
            .violation
            .unwrap_or_else(|| panic!("{name}: seeded bug escaped the model checker"));
        assert!(
            expected.contains(&v.kind),
            "{name}: found {:?}, expected one of {:?}",
            v.kind,
            expected
        );
    }
}
