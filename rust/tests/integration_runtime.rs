//! Integration: execute artifacts end-to-end through the registry's
//! backend seam.
//!
//! Hermetic by default: with no `artifacts/` directory (no XLA, no `make
//! artifacts`), `ArtifactRegistry::open` falls back to the pure-Rust
//! `ReferenceBackend`, which provides the standalone kernel artifacts,
//! the `ref_lm` decode step, AND (since PR 4) the `ref_lm` training
//! graphs — so the train-loop and conversion tests below run everywhere
//! instead of self-skipping. When compiled artifacts are present (and
//! the `pjrt` feature is enabled) the kernel tests exercise the compiled
//! path; the train-loop tests pin an explicit `ReferenceBackend` so they
//! stay hermetic in that environment too.

use hedgehog::runtime::{
    ref_lm_demo_params, ArtifactRegistry, ExecOptions, ReferenceBackend, Tensor, REF_LM2_TAG,
    REF_LM_TAG,
};
use hedgehog::serve::{Batcher, Engine, Request};
use hedgehog::train::session::{evaluate, ref_lm_demo_batch, Batch, Session};
use hedgehog::train::{convert, ConversionSpec};

fn registry() -> ArtifactRegistry {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    ArtifactRegistry::open(dir).expect("registry open must succeed without artifacts/")
}

/// A registry pinned to the reference backend: the builtin `ref_lm`
/// graphs exist regardless of what (if anything) is on disk.
fn ref_registry() -> ArtifactRegistry {
    ArtifactRegistry::with_backend("/nonexistent-artifacts", Box::new(ReferenceBackend::new()))
        .expect("reference registry must open with nothing on disk")
}

#[test]
fn registry_serves_kernels_without_artifacts_dir() {
    let reg = registry();
    let names = reg.names();
    assert!(names.contains(&"kernel_linear_attention"));
    assert!(names.contains(&"kernel_softmax_attention"));
    assert!(reg.manifest("kernel_linear_attention").unwrap().inputs.len() == 3);
    assert!(reg.get("definitely_not_an_artifact").is_err());
}

#[test]
fn kernel_linear_attention_runs_and_is_normalized() {
    let reg = registry();
    // (b=1, h=2, n=128, d=16) — the artifact applies exp() features itself,
    // so attention rows are convex combinations of v rows.
    let n = 1 * 2 * 128 * 16;
    let q: Vec<f32> = (0..n).map(|i| ((i * 37 % 97) as f32 / 97.0) - 0.5).collect();
    let k: Vec<f32> = (0..n).map(|i| ((i * 53 % 89) as f32 / 89.0) - 0.5).collect();
    let v = vec![1.0f32; n];
    let shape = [1usize, 2, 128, 16];
    let out = reg
        .run(
            "kernel_linear_attention",
            &[
                Tensor::from_f32(q, &shape),
                Tensor::from_f32(k, &shape),
                Tensor::from_f32(v, &shape),
            ],
        )
        .unwrap();
    let y = out[0].as_f32().unwrap();
    // all-ones values -> every output must be ~1 (weights sum to 1)
    for &x in y {
        assert!((x - 1.0).abs() < 1e-3, "got {x}");
    }
}

#[test]
fn kernel_softmax_attention_rows_are_convex() {
    let reg = registry();
    let n = 1 * 2 * 128 * 16;
    let q: Vec<f32> = (0..n).map(|i| ((i * 41 % 83) as f32 / 83.0) - 0.5).collect();
    let k: Vec<f32> = (0..n).map(|i| ((i * 59 % 79) as f32 / 79.0) - 0.5).collect();
    let v = vec![1.0f32; n];
    let shape = [1usize, 2, 128, 16];
    let out = reg
        .run(
            "kernel_softmax_attention",
            &[
                Tensor::from_f32(q, &shape),
                Tensor::from_f32(k, &shape),
                Tensor::from_f32(v, &shape),
            ],
        )
        .unwrap();
    for &x in out[0].as_f32().unwrap() {
        assert!((x - 1.0).abs() < 1e-3, "got {x}");
    }
}

#[test]
fn manifest_shapes_match_execution() {
    let reg = registry();
    let exe = reg.get("kernel_linear_attention").unwrap();
    // feeding wrong shapes must fail loudly
    let bad = vec![Tensor::scalar_f32(0.0); exe.manifest.inputs.len()];
    assert!(exe.run(&bad).is_err());
    // and so must feeding the wrong input count
    assert!(exe.run(&[Tensor::scalar_f32(0.0)]).is_err());
}

/// The serve stack end-to-end on the builtin decode artifact: registry ->
/// engine -> batcher, hermetic (no compiled artifacts, no XLA). Every
/// request must complete, FIFO per slot, with finite logits throughout —
/// and the same wave must produce identical outputs when the decode math
/// runs slot-parallel on the worker pool.
#[test]
fn serve_stack_runs_hermetically_on_reference_decode() {
    if registry().backend_name() != "reference" {
        // Compiled-artifact environments route through PJRT, which has no
        // builtin decode artifact; the serve path is covered there by the
        // model-graph examples instead.
        eprintln!("skipping: builtin ref_lm decode needs the reference backend");
        return;
    }
    let run_wave = |opts: ExecOptions| {
        let reg = registry();
        reg.set_exec_options(opts);
        let params = ref_lm_demo_params();
        let mut engine = Engine::new(&reg, REF_LM_TAG, &params).expect("builtin decode engine");
        let mut batcher = Batcher::new(engine.batch(), 64);
        for id in 0..10u64 {
            let plen = 1 + (id as usize % 4);
            let prompt: Vec<i32> = (0..plen).map(|i| (id as i32 * 13 + i as i32) % 256).collect();
            assert!(batcher.submit(Request { id, prompt, max_new: 5, eos: -1 }).is_ok());
        }
        let (steps, _secs) = batcher.run_to_completion(&mut engine).unwrap();
        assert!(steps > 0);
        assert_eq!(batcher.completed.len(), 10, "requests lost");
        let mut ids: Vec<u64> = batcher.completed.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
        for r in &batcher.completed {
            assert!(r.output.len() <= 5, "request {} over budget", r.id);
            assert!(r.output.iter().all(|&t| (0..256).contains(&t)), "token out of vocab");
        }
        let mut results: Vec<(u64, Vec<i32>)> =
            batcher.completed.iter().map(|r| (r.id, r.output.clone())).collect();
        results.sort();
        results
    };
    let serial = run_wave(ExecOptions::serial());
    let pooled = run_wave(ExecOptions::serial().with_threads(4));
    assert_eq!(serial, pooled, "slot-parallel decode changed the generated tokens");
}

/// The train loop end-to-end through the generic `Session` driver on the
/// builtin `ref_lm` graphs — init -> train_step x N -> eval — with no
/// artifacts directory and no XLA. This test used to self-skip without
/// compiled artifacts; the reference training path (runtime/ref_lm.rs)
/// makes it unconditional.
#[test]
fn init_train_eval_cycle_decreases_loss() {
    let reg = ref_registry();
    assert_eq!(reg.backend_name(), "reference");
    let mut s = Session::init(&reg, REF_LM_TAG, 0).unwrap();
    assert_eq!(s.params.len(), 2, "ref_lm has exactly embed + unembed");

    let steps = 40;
    let last = s.run(steps, |_| 1e-2, 0.0, |i| ref_lm_demo_batch(i % 3, false)).unwrap();
    assert_eq!(s.step, steps as i32, "step counter must thread through the graph");
    assert_eq!(s.losses.len(), steps);
    assert!(s.losses.iter().all(|l| l.is_finite()), "losses must stay finite");
    let first = s.losses[0];
    assert!(
        last < first * 0.8,
        "train loss did not decrease: {first} -> {last}"
    );

    // the eval graph runs against the trained params
    let (eval_loss, acc) = evaluate(&reg, REF_LM_TAG, &s.params, 2, |i| {
        ref_lm_demo_batch(i, false)
    })
    .unwrap();
    assert!(eval_loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
    assert!(
        eval_loss < first,
        "eval loss {eval_loss} should beat the untrained first loss {first}"
    );
}

/// The two-stage conversion pipeline (paper A.3) hermetically: teacher
/// train -> stage 1 attention distillation (loss decreasing over the
/// run) -> stage 2 finetune -> converted params drop straight into the
/// serve engine (train -> eval -> serve, one parameter layout).
#[test]
fn conversion_pipeline_runs_hermetically() {
    let reg = ref_registry();
    let mut teacher = Session::init(&reg, REF_LM_TAG, 1).unwrap();
    teacher.run(20, |_| 1e-2, 0.0, |_| ref_lm_demo_batch(0, false)).unwrap();

    let mut spec = ConversionSpec::new(REF_LM_TAG);
    spec.distill_steps = 50;
    spec.distill_lr = 1e-2;
    spec.finetune_steps = 20;
    spec.finetune_lr = 5e-3;
    spec.seed = 2;
    let conv = convert(
        &reg,
        &teacher.params,
        &spec,
        |_| ref_lm_demo_batch(0, true),
        |_| ref_lm_demo_batch(0, false),
    )
    .unwrap();

    assert_eq!(conv.shared_leaves, 2, "teacher and student share embed + unembed");
    assert_eq!(conv.distill_losses.len(), 50);
    assert_eq!(conv.finetune_losses.len(), 20);
    assert!(conv.distill_losses.iter().chain(&conv.finetune_losses).all(|l| l.is_finite()));
    let first10: f32 = conv.distill_losses[..10].iter().sum::<f32>() / 10.0;
    let last10: f32 = conv.distill_losses[40..].iter().sum::<f32>() / 10.0;
    assert!(
        last10 < first10,
        "distill loss did not decrease over the run: first10 {first10} vs last10 {last10}"
    );

    // converted params serve directly (decode shares the layout)
    let mut engine = Engine::new(&reg, REF_LM_TAG, &conv.params).unwrap();
    let (batch, vocab) = (engine.batch(), engine.vocab());
    let tokens = vec![3i32; batch];
    let logits = engine.step(&tokens).unwrap();
    assert_eq!(logits.len(), batch * vocab);
    assert!(logits.iter().all(|l| l.is_finite()), "served logits must be finite");
}

/// The same two-stage conversion on the 2-layer *learnable* builtin
/// (`ref_lm2`): per-layer projections + trainable feature maps, per-layer
/// Eq. 4 distillation summed over layers. All 14 leaves are shared
/// teacher -> student (self-family conversion), the distill loss must
/// decrease over 50 steps, and the converted params must serve through
/// the decode engine — the acceptance loop for the learnable config.
#[test]
fn conversion_pipeline_runs_hermetically_on_learnable_config() {
    let reg = ref_registry();
    let mut teacher = Session::init(&reg, REF_LM2_TAG, 1).unwrap();
    assert_eq!(teacher.params.len(), 14, "ref_lm2 has embed + 2x6 layer leaves + unembed");
    teacher.run(20, |_| 1e-2, 0.0, |_| ref_lm_demo_batch(0, false)).unwrap();

    let mut spec = ConversionSpec::new(REF_LM2_TAG);
    spec.distill_steps = 50;
    spec.distill_lr = 1e-2;
    spec.finetune_steps = 20;
    spec.finetune_lr = 5e-3;
    spec.seed = 2;
    let conv = convert(
        &reg,
        &teacher.params,
        &spec,
        |_| ref_lm_demo_batch(0, true),
        |_| ref_lm_demo_batch(0, false),
    )
    .unwrap();

    assert_eq!(conv.shared_leaves, 14, "every leaf is shared in self-family conversion");
    assert_eq!(conv.distill_losses.len(), 50);
    assert!(conv.distill_losses.iter().chain(&conv.finetune_losses).all(|l| l.is_finite()));
    let first10: f32 = conv.distill_losses[..10].iter().sum::<f32>() / 10.0;
    let last10: f32 = conv.distill_losses[40..].iter().sum::<f32>() / 10.0;
    assert!(
        last10 < first10 - 0.05,
        "per-layer distill loss did not decrease: first10 {first10} vs last10 {last10}"
    );

    let mut engine = Engine::new(&reg, REF_LM2_TAG, &conv.params).unwrap();
    let (batch, vocab) = (engine.batch(), engine.vocab());
    let logits = engine.step(&vec![3i32; batch]).unwrap();
    assert_eq!(logits.len(), batch * vocab);
    assert!(logits.iter().all(|l| l.is_finite()), "served logits must be finite");
}

/// Compiled-path coverage (needs `make artifacts` + the `pjrt` feature):
/// the same `Session` driver over the exported `ar_softmax` graphs, so
/// the compiled train plumbing keeps a test even though the hermetic
/// `ref_lm` tests above now cover the reference path unconditionally.
/// Self-skips everywhere else.
#[test]
fn compiled_model_graph_train_cycle() {
    let reg = registry();
    if reg.backend_name() != "pjrt"
        || !reg.contains("ar_softmax_init")
        || !reg.contains("ar_softmax_train_step")
    {
        eprintln!("skipping: needs compiled ar_softmax artifacts + the `pjrt` backend");
        return;
    }
    let mut s = Session::init(&reg, "ar_softmax", 0).unwrap();
    assert!(s.params.num_elements() > 10_000);
    let man = reg.manifest("ar_softmax_train_step").unwrap();
    let b = man.meta_usize("batch_size").unwrap_or(32);
    let n = man.meta_usize("seq_len").unwrap_or(64);
    // trivial batch: predict a constant token — loss must fall fast
    let batch = Batch::new()
        .with("tokens", Tensor::from_i32(vec![1; b * n], &[b, n]))
        .with("targets", Tensor::from_i32(vec![1; b * n], &[b, n]))
        .with("loss_mask", Tensor::from_f32(vec![1.0; b * n], &[b, n]));
    let last = s.run(5, |_| 1e-3, 0.0, |_| batch.clone()).unwrap();
    assert!(last < s.losses[0], "compiled train loss did not decrease");
    assert_eq!(s.step, 5);
}
