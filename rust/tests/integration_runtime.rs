//! Integration: execute artifacts end-to-end through the registry's
//! backend seam.
//!
//! Hermetic by default: with no `artifacts/` directory (no XLA, no `make
//! artifacts`), `ArtifactRegistry::open` falls back to the pure-Rust
//! `ReferenceBackend`, which provides and interprets the two standalone
//! kernel artifacts. When compiled artifacts are present (and the `pjrt`
//! feature is enabled) the same tests exercise the compiled path, and the
//! model-graph test below stops self-skipping.

use hedgehog::runtime::{
    ref_lm_demo_params, ArtifactRegistry, ExecOptions, ParamStore, Tensor, REF_LM_TAG,
};
use hedgehog::serve::{Batcher, Engine, Request};

fn registry() -> ArtifactRegistry {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    ArtifactRegistry::open(dir).expect("registry open must succeed without artifacts/")
}

#[test]
fn registry_serves_kernels_without_artifacts_dir() {
    let reg = registry();
    let names = reg.names();
    assert!(names.contains(&"kernel_linear_attention"));
    assert!(names.contains(&"kernel_softmax_attention"));
    assert!(reg.manifest("kernel_linear_attention").unwrap().inputs.len() == 3);
    assert!(reg.get("definitely_not_an_artifact").is_err());
}

#[test]
fn kernel_linear_attention_runs_and_is_normalized() {
    let reg = registry();
    // (b=1, h=2, n=128, d=16) — the artifact applies exp() features itself,
    // so attention rows are convex combinations of v rows.
    let n = 1 * 2 * 128 * 16;
    let q: Vec<f32> = (0..n).map(|i| ((i * 37 % 97) as f32 / 97.0) - 0.5).collect();
    let k: Vec<f32> = (0..n).map(|i| ((i * 53 % 89) as f32 / 89.0) - 0.5).collect();
    let v = vec![1.0f32; n];
    let shape = [1usize, 2, 128, 16];
    let out = reg
        .run(
            "kernel_linear_attention",
            &[
                Tensor::from_f32(q, &shape),
                Tensor::from_f32(k, &shape),
                Tensor::from_f32(v, &shape),
            ],
        )
        .unwrap();
    let y = out[0].as_f32().unwrap();
    // all-ones values -> every output must be ~1 (weights sum to 1)
    for &x in y {
        assert!((x - 1.0).abs() < 1e-3, "got {x}");
    }
}

#[test]
fn kernel_softmax_attention_rows_are_convex() {
    let reg = registry();
    let n = 1 * 2 * 128 * 16;
    let q: Vec<f32> = (0..n).map(|i| ((i * 41 % 83) as f32 / 83.0) - 0.5).collect();
    let k: Vec<f32> = (0..n).map(|i| ((i * 59 % 79) as f32 / 79.0) - 0.5).collect();
    let v = vec![1.0f32; n];
    let shape = [1usize, 2, 128, 16];
    let out = reg
        .run(
            "kernel_softmax_attention",
            &[
                Tensor::from_f32(q, &shape),
                Tensor::from_f32(k, &shape),
                Tensor::from_f32(v, &shape),
            ],
        )
        .unwrap();
    for &x in out[0].as_f32().unwrap() {
        assert!((x - 1.0).abs() < 1e-3, "got {x}");
    }
}

#[test]
fn manifest_shapes_match_execution() {
    let reg = registry();
    let exe = reg.get("kernel_linear_attention").unwrap();
    // feeding wrong shapes must fail loudly
    let bad = vec![Tensor::scalar_f32(0.0); exe.manifest.inputs.len()];
    assert!(exe.run(&bad).is_err());
    // and so must feeding the wrong input count
    assert!(exe.run(&[Tensor::scalar_f32(0.0)]).is_err());
}

/// The serve stack end-to-end on the builtin decode artifact: registry ->
/// engine -> batcher, hermetic (no compiled artifacts, no XLA). Every
/// request must complete, FIFO per slot, with finite logits throughout —
/// and the same wave must produce identical outputs when the decode math
/// runs slot-parallel on the worker pool.
#[test]
fn serve_stack_runs_hermetically_on_reference_decode() {
    if registry().backend_name() != "reference" {
        // Compiled-artifact environments route through PJRT, which has no
        // builtin decode artifact; the serve path is covered there by the
        // model-graph examples instead.
        eprintln!("skipping: builtin ref_lm decode needs the reference backend");
        return;
    }
    let run_wave = |opts: ExecOptions| {
        let reg = registry();
        reg.set_exec_options(opts);
        let params = ref_lm_demo_params();
        let mut engine = Engine::new(&reg, REF_LM_TAG, &params).expect("builtin decode engine");
        let mut batcher = Batcher::new(engine.batch, 64);
        for id in 0..10u64 {
            let plen = 1 + (id as usize % 4);
            let prompt: Vec<i32> = (0..plen).map(|i| (id as i32 * 13 + i as i32) % 256).collect();
            assert!(batcher.submit(Request { id, prompt, max_new: 5, eos: -1 }));
        }
        let (steps, _secs) = batcher.run_to_completion(&mut engine).unwrap();
        assert!(steps > 0);
        assert_eq!(batcher.completed.len(), 10, "requests lost");
        let mut ids: Vec<u64> = batcher.completed.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
        for r in &batcher.completed {
            assert!(r.output.len() <= 5, "request {} over budget", r.id);
            assert!(r.output.iter().all(|&t| (0..256).contains(&t)), "token out of vocab");
        }
        let mut results: Vec<(u64, Vec<i32>)> =
            batcher.completed.iter().map(|r| (r.id, r.output.clone())).collect();
        results.sort();
        results
    };
    let serial = run_wave(ExecOptions::serial());
    let pooled = run_wave(ExecOptions::serial().with_threads(4));
    assert_eq!(serial, pooled, "slot-parallel decode changed the generated tokens");
}

/// Model graphs need compiled artifacts (`make artifacts` + `pjrt`); the
/// test self-skips when they are absent so the suite stays hermetic.
#[test]
fn init_train_eval_cycle_decreases_loss() {
    let reg = registry();
    // Model graphs have no reference interpretation: require the PJRT
    // backend (not just manifests on disk) before driving them.
    if reg.backend_name() != "pjrt"
        || !reg.contains("ar_softmax_init")
        || !reg.contains("ar_softmax_train_step")
    {
        eprintln!("skipping: needs compiled ar_softmax artifacts + the `pjrt` backend");
        return;
    }
    let init = reg.get("ar_softmax_init").unwrap();
    let outs = init.run(&[Tensor::scalar_u32(0)]).unwrap();
    let mut params = ParamStore::from_outputs(&init.manifest.outputs, outs);
    assert!(params.num_elements() > 10_000);

    let step_exe = reg.get("ar_softmax_train_step").unwrap();
    let man = &step_exe.manifest;

    // zeroed optimizer state
    let mut opt = ParamStore::new();
    for slot in &man.inputs {
        if slot.name.starts_with("m/") || slot.name.starts_with("v/") {
            opt.insert(slot.name.clone(), Tensor::zeros(slot.dtype, &slot.shape));
        }
    }

    // trivial AR-ish batch: predict a constant token
    let b = 32;
    let nseq = 64;
    let tokens = Tensor::from_i32(vec![1; b * nseq], &[b, nseq]);
    let targets = Tensor::from_i32(vec![1; b * nseq], &[b, nseq]);
    let mask = Tensor::from_f32(vec![1.0; b * nseq], &[b, nseq]);

    let mut step = Tensor::scalar_i32(0);
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for _ in 0..5 {
        let mut inputs = Vec::new();
        for slot in &man.inputs {
            let t = match slot.name.as_str() {
                "step" => step.clone(),
                "lr" => Tensor::scalar_f32(1e-3),
                "wd" => Tensor::scalar_f32(0.0),
                "tokens" => tokens.clone(),
                "targets" => targets.clone(),
                "loss_mask" => mask.clone(),
                name if name.starts_with("params/") => params.get(name).unwrap().clone(),
                name => opt.get(name).unwrap().clone(),
            };
            inputs.push(t);
        }
        let outs = step_exe.run(&inputs).unwrap();
        // scatter params + opt back, read loss
        for (slot, t) in man.outputs.iter().zip(&outs) {
            if slot.name.starts_with("params/") {
                params.insert(slot.name.clone(), t.clone());
            } else if slot.name.starts_with("m/") || slot.name.starts_with("v/") {
                opt.insert(slot.name.clone(), t.clone());
            } else if slot.name == "step" {
                step = t.clone();
            } else if slot.name == "loss" {
                last_loss = t.item_f32().unwrap();
                first_loss.get_or_insert(last_loss);
            }
        }
    }
    assert!(
        last_loss < first_loss.unwrap(),
        "loss did not decrease: {first_loss:?} -> {last_loss}"
    );
    assert_eq!(step.item_i32().unwrap(), 5);
}
