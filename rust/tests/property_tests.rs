//! Property-based tests over coordinator invariants.
//!
//! The offline vendor set has no `proptest`, so these are randomized
//! property sweeps driven by the repo's deterministic PCG32 (seeds printed
//! on failure via assert messages — rerun with the same seed to reproduce).

use hedgehog::data::{ar::ArTask, corpus, glue, lra, samsum, Pcg32};
use hedgehog::metrics;
use hedgehog::runtime::reference::{prefill_state, prefill_state_with, PrefillScratch};
use hedgehog::runtime::simd::{self, SimdIsa};
use hedgehog::runtime::{ExecOptions, FeatureKind, ModelConfig, ParamStore, Tensor, WorkerPool};
use hedgehog::serve::{Batcher, Request};

const SWEEPS: u64 = 50;

// ---------------------------------------------------------------------------
// Batcher invariants (routing / batching / state)
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_conserves_requests() {
    for seed in 0..SWEEPS {
        let mut rng = Pcg32::new(seed);
        let cap = 1 + rng.usize_below(4);
        let n_req = 1 + rng.usize_below(20);
        let mut b = Batcher::new(cap, 1024);
        for id in 0..n_req as u64 {
            let prompt_len = 1 + rng.usize_below(5);
            let max_new = rng.usize_below(6);
            assert!(b.submit(Request {
                id,
                prompt: vec![1; prompt_len],
                max_new,
                eos: -1,
            }));
        }
        let mut guard = 0;
        while !b.is_idle() {
            b.plan_admissions();
            assert!(b.active() <= cap, "seed {seed}: capacity exceeded");
            let sampled: Vec<i32> = (0..cap).map(|_| 3 + rng.below(5) as i32).collect();
            b.record_tokens(&sampled);
            guard += 1;
            assert!(guard < 10_000, "seed {seed}: no termination");
        }
        // every request completes exactly once
        let mut ids: Vec<u64> = b.completed.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n_req, "seed {seed}: lost or duplicated requests");
        // outputs never exceed max_new
        for r in &b.completed {
            assert!(r.output.len() <= 6, "seed {seed}: output over budget");
        }
    }
}

#[test]
fn prop_batcher_fifo_admission() {
    // With capacity 1, completion order must equal submission order.
    for seed in 0..SWEEPS {
        let mut rng = Pcg32::new(seed ^ 0xfeed);
        let n_req = 2 + rng.usize_below(8);
        let mut b = Batcher::new(1, 1024);
        for id in 0..n_req as u64 {
            b.submit(Request {
                id,
                prompt: vec![1; 1 + rng.usize_below(3)],
                max_new: rng.usize_below(3),
                eos: -1,
            });
        }
        while !b.is_idle() {
            b.plan_admissions();
            b.record_tokens(&[7]);
        }
        let ids: Vec<u64> = b.completed.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort();
        assert_eq!(ids, sorted, "seed {seed}: FIFO violated");
    }
}

#[test]
fn prop_batcher_backpressure_bounded() {
    for seed in 0..SWEEPS {
        let mut rng = Pcg32::new(seed ^ 0xbeef);
        let max_q = 1 + rng.usize_below(5);
        let mut b = Batcher::new(1, max_q);
        let mut accepted = 0;
        for id in 0..20u64 {
            if b.submit(Request { id, prompt: vec![1], max_new: 1, eos: -1 }) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, max_q, "seed {seed}");
        assert_eq!(b.rejected, 20 - max_q, "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// Metric invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_matthews_bounded_and_symmetric() {
    for seed in 0..SWEEPS {
        let mut rng = Pcg32::new(seed);
        let n = 4 + rng.usize_below(64);
        let p: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let l: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let m = metrics::matthews(&p, &l);
        assert!((-1.0..=1.0).contains(&m), "seed {seed}: mc {m}");
        // symmetry: mc(p, l) == mc(l, p)
        let m2 = metrics::matthews(&l, &p);
        assert!((m - m2).abs() < 1e-6, "seed {seed}");
    }
}

#[test]
fn prop_spearman_invariant_to_monotone_transform() {
    for seed in 0..SWEEPS {
        let mut rng = Pcg32::new(seed);
        let n = 5 + rng.usize_below(40);
        let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let r1 = metrics::spearman(&x, &y);
        // exp() is strictly monotone: ranks unchanged
        let xe: Vec<f32> = x.iter().map(|v| v.exp()).collect();
        let r2 = metrics::spearman(&xe, &y);
        assert!((r1 - r2).abs() < 1e-4, "seed {seed}: {r1} vs {r2}");
    }
}

#[test]
fn prop_rouge_bounds_and_identity() {
    for seed in 0..SWEEPS {
        let mut rng = Pcg32::new(seed);
        let n = 1 + rng.usize_below(12);
        let a: Vec<i32> = (0..n).map(|_| rng.below(8) as i32).collect();
        let m = 1 + rng.usize_below(12);
        let b: Vec<i32> = (0..m).map(|_| rng.below(8) as i32).collect();
        let (r1, r2, rl) = metrics::rouge_scores(&a, &b);
        for v in [r1, r2, rl] {
            assert!((0.0..=100.0 + 1e-3).contains(&v), "seed {seed}: {v}");
        }
        let (i1, _, il) = metrics::rouge_scores(&a, &a);
        assert!((i1 - 100.0).abs() < 1e-3 && (il - 100.0).abs() < 1e-3, "seed {seed}");
    }
}

#[test]
fn prop_kl_nonnegative_on_distributions() {
    for seed in 0..SWEEPS {
        let mut rng = Pcg32::new(seed);
        let n = 2 + rng.usize_below(16);
        let norm = |v: Vec<f32>| {
            let s: f32 = v.iter().sum();
            v.into_iter().map(|x| x / s).collect::<Vec<f32>>()
        };
        let p = norm((0..n).map(|_| rng.f32() + 0.01).collect());
        let q = norm((0..n).map(|_| rng.f32() + 0.01).collect());
        assert!(metrics::kl_div(&p, &q) > -1e-4, "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// Data-generator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_ar_answer_always_recallable() {
    let task = ArTask::default_for_family();
    for seed in 0..SWEEPS {
        let mut rng = Pcg32::new(seed);
        let (t, g, m) = task.sample(&mut rng);
        let pos = m.iter().position(|&x| x == 1.0).expect("one supervised pos");
        let key = t[pos];
        let ans = g[pos];
        let mut found = false;
        let mut i = 0;
        while i + 1 < pos {
            if t[i] == key && t[i + 1] == ans {
                found = true;
                break;
            }
            i += 2;
        }
        assert!(found, "seed {seed}: unanswerable AR sample");
    }
}

#[test]
fn prop_corpus_tokens_in_vocab_and_deterministic() {
    for seed in 0..20 {
        let lang = corpus::TinyLanguage::new(256);
        let mut r1 = Pcg32::new(seed);
        let mut r2 = Pcg32::new(seed);
        let a = lang.stream(&mut r1, corpus::Domain::Pretrain, 2048);
        let b = lang.stream(&mut r2, corpus::Domain::Pretrain, 2048);
        assert_eq!(a, b, "seed {seed}: nondeterministic corpus");
        assert!(a.iter().all(|&t| (t as usize) < 256));
    }
}

#[test]
fn prop_glue_labels_match_structure() {
    // qnli is fully checkable: label <-> query containment
    for seed in 0..SWEEPS {
        let mut rng = Pcg32::new(seed);
        let (t, l) = glue::sample(glue::GlueTask::Qnli, &mut rng);
        assert_eq!(t[2..].contains(&t[0]), l > 0.5, "seed {seed}");
    }
}

#[test]
fn prop_lra_sequences_sized() {
    for seed in 0..20 {
        let mut rng = Pcg32::new(seed);
        for task in lra::ALL_TASKS {
            let (t, t2, _) = lra::sample(task, &mut rng);
            assert_eq!(t.len(), task.seq_len());
            if let Some(t2) = t2 {
                assert_eq!(t2.len(), task.seq_len());
            }
        }
    }
}

#[test]
fn prop_samsum_masks_inside_sequence() {
    for seed in 0..SWEEPS {
        let mut rng = Pcg32::new(seed);
        let s = samsum::sample(&mut rng);
        // supervised positions all fall before the final pad run
        let last_nonpad = s.tokens.iter().rposition(|&t| t != samsum::PAD).unwrap();
        for (i, &m) in s.mask.iter().enumerate() {
            if m > 0.0 {
                assert!(i <= last_nonpad, "seed {seed}: mask on pure padding");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD dispatch tiers + pooled prefill (DESIGN.md §13)
// ---------------------------------------------------------------------------

/// Seeded params + the manifest-ordered leaf list for a config (the same
/// sorted layout `builtin_decode_manifest` exposes as `inputs[4..]`).
fn prefill_params(cfg: &ModelConfig) -> ParamStore {
    cfg.init_params(0x5EED)
}

fn leaf_refs<'a>(cfg: &ModelConfig, params: &'a ParamStore) -> Vec<&'a Tensor> {
    cfg.leaf_slots("params").iter().map(|sl| params.get(&sl.name).unwrap()).collect()
}

/// The non-scalar tiers this host can run (lanes8 always; avx2 where the
/// CPU has AVX2+FMA — CI's dispatch matrix covers the avx2 leg on hosts
/// that skip it here).
fn host_tiers() -> Vec<SimdIsa> {
    let mut tiers = vec![SimdIsa::Lanes8];
    if simd::avx2_supported() {
        tiers.push(SimdIsa::Avx2);
    } else {
        eprintln!("host lacks AVX2+FMA — avx2 tier parity covered by CI's matrix leg only");
    }
    tiers
}

/// Every dispatch tier must agree with the scalar oracle to <= 1e-5
/// relative, for every feature map in the zoo, across a chunk grid
/// (including the non-divisor chunk and the one-block naive path). The
/// whole-model prefill composes every `runtime::simd` kernel the decode
/// hot path uses — dot/axpy/scaled_add/rank1_update and each map's
/// exp/relu/dpfp feature pipeline — so this is the end-to-end tier
/// parity gate on top of simd.rs's per-kernel unit sweeps.
#[test]
fn prop_prefill_tier_parity_across_feature_zoo() {
    let prompt: Vec<i32> = vec![3, 250, 17, 17, 99, 0, 42, 128, 7, 64, 9, 77, 5];
    for kind in FeatureKind::zoo() {
        let cfg = ModelConfig { feature: kind, ..ModelConfig::ref_lm2() };
        let params = prefill_params(&cfg);
        let leaves = leaf_refs(&cfg, &params);
        let grid = [
            ExecOptions::serial(),
            ExecOptions { threads: 1, chunk_size: 5 },
            ExecOptions::naive(),
        ];
        for opts in grid {
            let (os, oz, ol) = simd::with_isa(SimdIsa::Scalar, || {
                prefill_state(&cfg, &leaves, &prompt, opts).unwrap()
            });
            for &isa in &host_tiers() {
                let (ts, tz, tl) =
                    simd::with_isa(isa, || prefill_state(&cfg, &leaves, &prompt, opts).unwrap());
                for (what, got, want) in [("S", &ts, &os), ("z", &tz, &oz), ("logits", &tl, &ol)]
                {
                    assert_eq!(got.len(), want.len(), "{} {what}: length", kind.name());
                    for (i, (x, y)) in got.iter().zip(want).enumerate() {
                        let tol = 1e-5 * y.abs().max(1.0);
                        assert!(
                            (x - y).abs() <= tol,
                            "{} {what}[{i}] ({opts:?}, {isa:?}): tier {x} vs scalar {y}",
                            kind.name()
                        );
                    }
                }
            }
        }
    }
}

/// Pool-parallel prefill must be *bit-identical* to the inline pass with
/// the same options, for every builtin tag, thread count, and dispatch
/// tier: every stage-2 head fold and stage-1/3 row block runs the same
/// `simd` call sequence on the same operands whichever worker claims it,
/// and pool workers inherit the dispatcher's tier (a worker falling back
/// to a different tier would break exact equality here). Together with
/// `prefill_matches_sequential_decode` (reference.rs, <= 1e-5 vs n
/// decode steps) this closes the pooled-prefill state-handoff contract.
#[test]
fn prop_pooled_prefill_bit_identical_to_inline() {
    let prompt: Vec<i32> = vec![3, 250, 17, 17, 99, 0, 42, 128, 7, 64, 9, 77, 5, 12, 201];
    let pool = WorkerPool::new();
    let mut scratch = PrefillScratch::new();
    for tag in ModelConfig::builtin_tags() {
        let cfg = ModelConfig::for_tag(tag).unwrap();
        let params = prefill_params(&cfg);
        let leaves = leaf_refs(&cfg, &params);
        for &isa in &host_tiers() {
            for threads in [2usize, 3, 4] {
                for chunk in [5usize, ExecOptions::DEFAULT_CHUNK] {
                    let opts = ExecOptions { threads, chunk_size: chunk };
                    let inline_opts = ExecOptions { threads: 1, chunk_size: chunk };
                    let (ws, wz, wl) = simd::with_isa(isa, || {
                        prefill_state(&cfg, &leaves, &prompt, inline_opts).unwrap()
                    });
                    let (gs, gz, gl) = simd::with_isa(isa, || {
                        prefill_state_with(
                            &cfg,
                            &leaves,
                            &prompt,
                            opts,
                            Some(&pool),
                            &mut scratch,
                        )
                        .unwrap()
                    });
                    for (what, got, want) in
                        [("S", &gs, &ws), ("z", &gz, &wz), ("logits", &gl, &wl)]
                    {
                        assert!(
                            got.iter().zip(want.iter()).all(|(a, b)| a.to_bits() == b.to_bits())
                                && got.len() == want.len(),
                            "{tag} {what} ({isa:?}, t={threads}, C={chunk}): pooled prefill \
                             diverged from the inline pass"
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scheduler outcome accounting (DESIGN.md §11)
// ---------------------------------------------------------------------------

/// Whatever the lifecycle policy (deadlines and shedding on or off),
/// every submitted request resolves to exactly one typed outcome:
/// `completed + shed + poisoned + deadline_exceeded + rejected ==
/// submitted`, with unique ids and counters that agree with the
/// per-request records. Fewer sweeps than the pure-state-machine props —
/// each sweep drives a real decode engine.
#[test]
fn prop_scheduler_resolves_every_request_to_one_outcome() {
    use hedgehog::runtime::{ref_lm_demo_params, ArtifactRegistry, ExecOptions, REF_LM_TAG};
    use hedgehog::serve::{Engine, Outcome, Scheduler, ServePolicy, TrafficGen};

    for seed in 0..8u64 {
        let reg = ArtifactRegistry::open("/nonexistent/artifacts-dir").unwrap();
        reg.set_exec_options(ExecOptions::serial());
        let mut engine = Engine::new(&reg, REF_LM_TAG, &ref_lm_demo_params()).unwrap();
        let cap = engine.batch();
        let mut rng = Pcg32::new(seed ^ 0x5C4ED);
        // randomize the policy: each knob independently off or small
        let deadline = if rng.bool(0.5) { 6 + rng.usize_below(30) } else { 0 };
        let shed = if rng.bool(0.5) { 2 + rng.usize_below(10) } else { 0 };
        let policy = ServePolicy {
            deadline_ticks: deadline,
            shed_queue_ticks: shed,
            ..ServePolicy::default()
        };
        let mut sched = Scheduler::with_policy(cap, 1 + rng.usize_below(2 * cap), policy);
        let mut gen = TrafficGen::new(seed, 0.5 + f64::from(rng.f32()), (1, 10), (1, 8), 32, -1);
        let target = 15 + rng.usize_below(15) as u64;

        let mut submitted = 0usize;
        let mut clock = 0usize;
        while gen.generated() < target || !sched.is_idle() {
            if gen.generated() < target {
                while let Some(req) = gen.next_if_due(clock) {
                    submitted += 1;
                    let _ = sched.submit(req);
                    if gen.generated() >= target {
                        break;
                    }
                }
            }
            sched.tick(&mut engine, &mut |_, _| {}).unwrap();
            clock += 1;
            assert!(clock < 10_000, "seed {seed}: no termination");
        }

        assert_eq!(
            sched.completed.len() + sched.rejected,
            submitted,
            "seed {seed}: lost or duplicated requests (policy {policy:?})"
        );
        let mut ids: Vec<u64> = sched.completed.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let n = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), n, "seed {seed}: a request resolved twice");
        let by = |o: Outcome| sched.completed.iter().filter(|r| r.outcome == o).count();
        assert_eq!(by(Outcome::Shed), sched.shed, "seed {seed}");
        assert_eq!(by(Outcome::DeadlineExceeded), sched.deadline_exceeded, "seed {seed}");
        assert_eq!(by(Outcome::Poisoned), sched.poisoned, "seed {seed}");
        assert_eq!(by(Outcome::Poisoned), 0, "seed {seed}: fault-free runs never poison");
        assert_eq!(
            by(Outcome::Completed) + sched.shed + sched.deadline_exceeded + sched.poisoned,
            sched.completed.len(),
            "seed {seed}: counters disagree with records"
        );
        if deadline == 0 && shed == 0 {
            assert!(
                sched.completed.iter().all(|r| r.outcome == Outcome::Completed),
                "seed {seed}: default lifecycle must resolve everything Completed"
            );
        }
        for r in sched.completed.iter().filter(|r| r.outcome == Outcome::Shed) {
            assert!(r.output.is_empty(), "seed {seed}: shed request streamed tokens");
            assert_eq!(r.ttft, None, "seed {seed}: shed request has a first token");
        }
    }
}
