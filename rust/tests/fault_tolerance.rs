//! Fault-tolerance integration tests (DESIGN.md §11): seeded chaos
//! against the serve stack and kill/resume against the train stack.
//!
//! Everything here is hermetic (reference backend builtins, no
//! artifacts on disk) and deterministic: chaos schedules are pure
//! functions of a seed via [`FaultPlan`], so a failure replays
//! byte-for-byte. Injected worker panics print their unwind message to
//! stderr — in this test binary those lines are expected output, not a
//! crash (the pool contains them and the scheduler retries).

use hedgehog::runtime::{
    ref_lm_demo_params, ArtifactRegistry, ChaosBackend, ExecOptions, FaultEvent, FaultKind,
    FaultPlan, FaultRates, PoolError, TransientExecError, REF_LM_TAG,
};
use hedgehog::serve::{Engine, Outcome, Request, Scheduler, ServePolicy, TrafficGen};
use hedgehog::train::session::{ref_lm_demo_batch, Session};

fn chaos_registry(plan: FaultPlan) -> ArtifactRegistry {
    let (chaos, _handle) = ChaosBackend::with_plan(plan);
    let reg = ArtifactRegistry::with_backend("/nonexistent/artifacts-dir", Box::new(chaos))
        .expect("chaos registry");
    reg.set_exec_options(ExecOptions::serial());
    reg
}

fn ref_registry() -> ArtifactRegistry {
    let reg = ArtifactRegistry::open("/nonexistent/artifacts-dir").expect("reference registry");
    reg.set_exec_options(ExecOptions::serial());
    reg
}

/// Drive a scheduler + traffic generator to idle, submitting everything
/// the generator produces. Returns how many requests were submitted.
fn drive_to_idle(
    sched: &mut Scheduler,
    engine: &mut Engine,
    gen: &mut TrafficGen,
    target: u64,
) -> usize {
    let mut submitted = 0usize;
    let mut clock = 0usize;
    while gen.generated() < target || !sched.is_idle() {
        if gen.generated() < target {
            while let Some(req) = gen.next_if_due(clock) {
                submitted += 1;
                let _ = sched.submit(req); // QueueFull -> counted in rejected
                if gen.generated() >= target {
                    break;
                }
            }
        }
        sched.tick(engine, &mut |_, _| {}).expect("tick must absorb transient faults");
        clock += 1;
        assert!(clock < 100_000, "chaos run failed to drain (livelock?)");
    }
    submitted
}

/// Every injected fault kind fires on its scheduled decode-execute
/// ordinal and surfaces through the typed channel the design names:
/// pool panics and transient errors as retryable step errors, logits
/// and state corruption as a single-slot quarantine.
#[test]
fn each_fault_kind_surfaces_through_its_typed_channel() {
    let plan = FaultPlan::from_events(vec![
        FaultEvent { step: 0, kind: FaultKind::WorkerPanic, slot: 0, value: 0.0 },
        FaultEvent { step: 1, kind: FaultKind::TransientError, slot: 0, value: 0.0 },
        FaultEvent { step: 2, kind: FaultKind::CorruptLogits, slot: 0, value: f32::NAN },
        FaultEvent { step: 3, kind: FaultKind::CorruptState, slot: 1, value: f32::INFINITY },
    ]);
    let reg = chaos_registry(plan);
    let mut engine = Engine::new(&reg, REF_LM_TAG, &ref_lm_demo_params()).unwrap();
    let toks = vec![3i32; engine.batch()];

    // ordinal 0: a real unwinding task, contained by the pool
    let err = engine.step(&toks).expect_err("injected panic must fail the step");
    assert!(err.downcast_ref::<PoolError>().is_some(), "want PoolError, got: {err:#}");
    // ordinal 1: retryable executor fault, fired before the math ran
    let err = engine.step(&toks).expect_err("injected transient must fail the step");
    assert!(err.downcast_ref::<TransientExecError>().is_some(), "want transient, got: {err:#}");
    // failed pre-execute steps never advanced the state
    assert!(engine.positions().iter().all(|&p| p == 0), "failed step advanced positions");

    // ordinal 2: NaN in slot 0's logits row -> only slot 0 quarantined
    engine.step(&toks).expect("corruption does not fail the step");
    assert_eq!(engine.quarantined(), 0b01, "logits poison quarantines slot 0 only");
    // ordinal 3: Inf in slot 1's state column -> only slot 1 quarantined
    engine.step(&toks).expect("corruption does not fail the step");
    assert_eq!(engine.quarantined(), 0b10, "state poison quarantines slot 1 only");
    // past the plan: clean steps, scrubbed state stays healthy
    engine.step(&toks).unwrap();
    assert_eq!(engine.quarantined(), 0);
    assert_eq!(engine.slots.health_check(), 0, "scrub left no poison behind");
}

/// The outcome-accounting invariant under a high-rate seeded storm of
/// every executor fault family: the process never aborts, ticks never
/// fail, and every submitted request resolves to exactly one outcome.
#[test]
fn chaos_storm_resolves_every_request_exactly_once() {
    let rates = FaultRates {
        corrupt_state: 0.05,
        corrupt_logits: 0.05,
        worker_panic: 0.03,
        transient: 0.03,
        burst: 0.0,
    };
    let (chaos, handle) = ChaosBackend::new(0xFA7A1, 4096, 4, &rates);
    let reg = ArtifactRegistry::with_backend("/nonexistent/artifacts-dir", Box::new(chaos))
        .expect("chaos registry");
    reg.set_exec_options(ExecOptions::serial());
    let mut engine = Engine::new(&reg, REF_LM_TAG, &ref_lm_demo_params()).unwrap();
    let cap = engine.batch();
    let policy = ServePolicy {
        deadline_ticks: 300,
        shed_queue_ticks: 60,
        max_step_retries: 10,
        retry_backoff_ticks: 1,
    };
    let mut sched = Scheduler::with_policy(cap, 2 * cap, policy);
    let mut gen = TrafficGen::new(0x57A4, 0.9, (2, 8), (2, 8), engine.vocab(), -1);

    let submitted = drive_to_idle(&mut sched, &mut engine, &mut gen, 50);

    assert_eq!(
        sched.completed.len() + sched.rejected,
        submitted,
        "a request was lost or duplicated under chaos"
    );
    let mut ids: Vec<u64> = sched.completed.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    let n = ids.len();
    ids.dedup();
    assert_eq!(ids.len(), n, "a request resolved twice");
    // per-request records agree with the aggregate outcome counters
    let by = |o: Outcome| sched.completed.iter().filter(|r| r.outcome == o).count();
    assert_eq!(by(Outcome::Shed), sched.shed);
    assert_eq!(by(Outcome::Poisoned), sched.poisoned);
    assert_eq!(by(Outcome::DeadlineExceeded), sched.deadline_exceeded);
    assert_eq!(
        by(Outcome::Completed) + sched.shed + sched.poisoned + sched.deadline_exceeded,
        sched.completed.len()
    );
    // the storm actually stormed, and the loop actually absorbed it
    assert!(handle.injected().total() > 0, "chaos plan injected nothing");
    let inj = handle.injected();
    assert_eq!(sched.transient_faults, inj.worker_panics + inj.transients);
}

/// Quarantine blast radius: corrupting one slot must not perturb any
/// other request's output. Requests that complete both fault-free and
/// under a corruption-only chaos plan stream byte-identical tokens.
#[test]
fn quarantine_leaves_other_requests_byte_identical() {
    let requests: Vec<Request> = (0..40u64)
        .map(|i| Request {
            id: i,
            prompt: vec![(1 + i % 7) as i32, (2 + i % 11) as i32, (3 + i % 5) as i32],
            max_new: 3 + (i % 4) as usize,
            eos: -1,
        })
        .collect();

    let run = |reg: &ArtifactRegistry| -> Scheduler {
        let mut engine = Engine::new(reg, REF_LM_TAG, &ref_lm_demo_params()).unwrap();
        let mut sched = Scheduler::new(engine.batch(), requests.len());
        for req in &requests {
            sched.submit(req.clone()).unwrap();
        }
        let mut ticks = 0usize;
        while !sched.is_idle() {
            sched.tick(&mut engine, &mut |_, _| {}).unwrap();
            ticks += 1;
            assert!(ticks < 100_000, "run failed to drain");
        }
        sched
    };

    let clean = run(&ref_registry());
    // ~0.19/step corruption probability over ~50 decode steps: several
    // requests get poisoned, most still complete. One pinned event on
    // top of the seeded plan guarantees at least one quarantine fires
    // while the batch is full, whatever the seed rolls.
    let rates =
        FaultRates { corrupt_state: 0.1, corrupt_logits: 0.1, ..FaultRates::default() };
    let mut events = FaultPlan::generate(0xB1A57, 4096, 4, &rates).events().to_vec();
    events.push(FaultEvent { step: 10, kind: FaultKind::CorruptLogits, slot: 2, value: f32::NAN });
    let (chaos, _handle) = ChaosBackend::with_plan(FaultPlan::from_events(events));
    let reg = ArtifactRegistry::with_backend("/nonexistent/artifacts-dir", Box::new(chaos))
        .expect("chaos registry");
    reg.set_exec_options(ExecOptions::serial());
    let chaotic = run(&reg);

    assert_eq!(clean.completed.len(), requests.len());
    assert_eq!(clean.poisoned, 0, "fault-free run must not quarantine");
    assert!(chaotic.poisoned >= 1, "rates this high must poison someone in 40 requests");
    assert_eq!(
        chaotic.completed.len() + chaotic.rejected,
        requests.len(),
        "accounting must survive quarantines"
    );
    let output_of = |s: &Scheduler, id: u64| -> Option<Vec<i32>> {
        s.completed
            .iter()
            .find(|r| r.id == id && r.outcome == Outcome::Completed)
            .map(|r| r.output.clone())
    };
    let mut compared = 0usize;
    for req in &requests {
        if let (Some(a), Some(b)) = (output_of(&clean, req.id), output_of(&chaotic, req.id)) {
            assert_eq!(a, b, "request {} diverged under someone else's quarantine", req.id);
            compared += 1;
        }
    }
    assert!(compared >= 10, "only {compared} requests completed in both runs");
}

/// Kill-and-resume: a session checkpointed at step k and resumed in a
/// fresh registry (a fresh process, morally) produces bit-identical
/// losses from step k+1 on.
#[test]
fn kill_and_resume_is_bit_identical() {
    let reg = ref_registry();
    let mut full = Session::init(&reg, REF_LM_TAG, 7).unwrap();
    full.run(10, |_| 1e-2, 0.0, |i| ref_lm_demo_batch(i, false)).unwrap();

    let reg_b = ref_registry();
    let mut killed = Session::init(&reg_b, REF_LM_TAG, 7).unwrap();
    killed.run(5, |_| 1e-2, 0.0, |i| ref_lm_demo_batch(i, false)).unwrap();
    let ckpt = std::env::temp_dir().join("hh_ft_resume.ckpt");
    killed.checkpoint(&ckpt).unwrap();
    drop(killed);
    drop(reg_b);

    let reg_c = ref_registry();
    let mut resumed =
        Session::resume(&reg_c, &format!("{REF_LM_TAG}_train_step"), &ckpt).unwrap();
    std::fs::remove_file(&ckpt).ok();
    assert_eq!(resumed.step, 5, "checkpoint must carry the step counter");
    assert!(resumed.losses.is_empty(), "loss history is telemetry, not state");
    resumed.run(5, |_| 1e-2, 0.0, |i| ref_lm_demo_batch(5 + i, false)).unwrap();

    assert_eq!(resumed.losses.len(), 5);
    for (k, (a, b)) in full.losses[5..].iter().zip(&resumed.losses).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "loss diverged at step {} (uninterrupted {a} vs resumed {b})",
            5 + k
        );
    }
}

/// `run_guarded` end to end: a poisoned batch cursor is skipped, the
/// session rolls back to the last checkpoint, and training still lands
/// the requested number of finite steps.
#[test]
fn guarded_run_skips_poison_and_rolls_back() {
    let reg = ref_registry();
    let mut s = Session::init(&reg, REF_LM_TAG, 11).unwrap();
    let ckpt = std::env::temp_dir().join("hh_ft_guarded.ckpt");
    let report = s
        .run_guarded(
            10,
            |_| 1e-2,
            0.0,
            |cursor| {
                let mut b = ref_lm_demo_batch(cursor, false);
                if cursor == 6 {
                    for (name, t) in b.slots.iter_mut() {
                        if name == "loss_mask" {
                            t.as_f32_mut().unwrap()[0] = f32::NAN;
                        }
                    }
                }
                b
            },
            &ckpt,
            4,
        )
        .unwrap();
    std::fs::remove_file(&ckpt).ok();

    assert_eq!(report.steps, 10);
    assert_eq!(report.skipped, vec![6], "exactly the poisoned cursor is skipped");
    assert_eq!(report.rollbacks, 1);
    assert_eq!(report.checkpoints, 3, "entry + steps 4 and 8");
    assert!(report.final_loss.is_finite());
    assert_eq!(s.step, 10, "10 optimizer steps landed despite the rollback");
    assert_eq!(s.losses.len(), 10, "replayed losses were truncated, not duplicated");
    assert!(s.losses.iter().all(|l| l.is_finite()));
}
