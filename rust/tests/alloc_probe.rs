//! Heap probe for the reference backend's hot loops: execution may
//! allocate a bounded number of buffers (the output tensors, per-task
//! scratch), but the number of allocations must NOT scale with sequence
//! length or decode position — feature extraction and the per-row /
//! per-chunk / per-token loops are allocation-free by construction
//! (`FeatureMap::write` into hoisted scratch, persistent token/pos
//! buffers and double-buffered (S, z) in `serve::Engine`).
//!
//! Single `#[test]` in its own binary: the counting allocator is
//! process-global and libtest runs tests in one process concurrently, so
//! keeping every probe inside one sequential test function keeps the
//! counts deterministic. Most probes run with threads=1 (the inline pool
//! path spawns nothing and takes no locks); the sharded-decode probe
//! deliberately runs threads=2 — the pool's claim-counter dispatch is
//! allocation-free by design, so a measured window of zero stays
//! deterministic even with a live worker thread.

// Integration tests are separate crates: the soundness-gate lint from
// src/lib.rs must be re-armed here (DESIGN.md §12).
#![deny(unsafe_op_in_unsafe_fn)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

use hedgehog::runtime::backend::Executable as _;
use hedgehog::runtime::reference::kernel_manifest;
use hedgehog::runtime::{
    ref_lm_demo_params, ArtifactRegistry, Backend, ExecOptions, ReferenceBackend, Tensor,
    REF_LM_TAG,
};
use hedgehog::serve::Engine;

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

// SAFETY: delegates every operation to `System`; only adds a counter.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: signature dictated by `GlobalAlloc`; the caller's
    // obligations are the trait's, discharged in the inner block.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: forwarding the caller's layout to `System` unchanged —
        // the caller's `GlobalAlloc` obligations carry over verbatim.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: trait-dictated signature, as for `alloc`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr` came from `alloc`/`realloc` above, which return
        // `System` pointers, so releasing through `System` with the same
        // layout is the matching pair.
        unsafe { System.dealloc(ptr, layout) }
    }

    // SAFETY: trait-dictated signature, as for `alloc`.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same forwarding argument as `dealloc` — `ptr` is a
        // live `System` allocation with this layout.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_calls_during(f: impl FnOnce()) -> usize {
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    f();
    ALLOC_CALLS.load(Ordering::Relaxed) - before
}

/// Allocation calls per execute for one (kernel, n, opts) config, after a
/// warmup call so one-time lazy init never pollutes the count.
fn allocs_for(kernel: &str, n: usize, opts: ExecOptions) -> usize {
    let shape = [1usize, 2, n, 8];
    let len: usize = shape.iter().product();
    let backend = ReferenceBackend::with_options(opts);
    let exe = backend.load(Path::new("unused"), &kernel_manifest(kernel, &shape)).unwrap();
    let mk = |seed: usize| {
        let data = (0..len).map(|i| ((i * 31 + seed) % 97) as f32 / 97.0 - 0.5).collect();
        Tensor::from_f32(data, &shape)
    };
    let inputs = [mk(1), mk(2), mk(3)];
    let refs: Vec<&Tensor> = inputs.iter().collect();
    exe.execute(&refs).unwrap(); // warmup
    alloc_calls_during(|| {
        let out = exe.execute(&refs).unwrap();
        std::hint::black_box(&out);
        drop(out);
    })
}

fn kernel_probe() {
    for kernel in ["kernel_linear_attention", "kernel_softmax_attention"] {
        // Chunked path, fixed chunk size: 4x the rows, 4x the chunks —
        // same number of allocator calls.
        let chunked = ExecOptions { threads: 1, chunk_size: 16 };
        let small = allocs_for(kernel, 64, chunked);
        let large = allocs_for(kernel, 256, chunked);
        assert_eq!(
            small, large,
            "{kernel} chunked: allocation count scales with n (n=64: {small}, n=256: {large})"
        );
        // Naive oracle path: per-row loops must also be allocation-free.
        let naive_small = allocs_for(kernel, 64, ExecOptions::naive());
        let naive_large = allocs_for(kernel, 256, ExecOptions::naive());
        assert_eq!(
            naive_small, naive_large,
            "{kernel} naive: allocation count scales with n \
             (n=64: {naive_small}, n=256: {naive_large})"
        );
        // Sanity: the counter actually observes this workload.
        assert!(small > 0, "{kernel}: counting allocator saw nothing");
    }
}

/// Allocation calls for one `Engine::step` after the engine has already
/// advanced to `position` (every slot fed the same token stream).
fn decode_allocs_at(engine: &mut Engine, position: usize) -> usize {
    let toks = vec![3i32; engine.batch()];
    while (engine.positions()[0] as usize) < position {
        engine.step(&toks).unwrap();
    }
    alloc_calls_during(|| {
        let logits = engine.step(&toks).unwrap();
        std::hint::black_box(logits);
    })
}

fn decode_probe() {
    let reg = ArtifactRegistry::open("/nonexistent/artifacts-dir").unwrap();
    reg.set_exec_options(ExecOptions::serial());
    let params = ref_lm_demo_params();
    let mut engine = Engine::new(&reg, REF_LM_TAG, &params).unwrap();
    let early = decode_allocs_at(&mut engine, 8);
    let mid = decode_allocs_at(&mut engine, 64);
    let late = decode_allocs_at(&mut engine, 512);
    // ZERO steady-state allocations (PR 5): `Engine::step` assembles its
    // borrowed inputs through a persistent pointer scratch and the
    // reference decode's `execute_into` writes logits and the advanced
    // (S, z) straight into the engine's swapped back buffers — after
    // warmup there is nothing left to allocate on the serial path.
    assert_eq!(early, 0, "Engine::step allocated {early} times per token (want 0)");
    assert_eq!(mid, 0, "Engine::step allocated {mid} times per token at pos 64 (want 0)");
    assert_eq!(late, 0, "Engine::step allocated {late} times per token at pos 512 (want 0)");
}

/// Same decode-tick contract on the *sharded* pool path (DESIGN.md §13):
/// with an explicit threads=2 dispatch the step executor fans the slots
/// out over pool tasks through `WorkerPool::run`'s claim-counter
/// dispatch — no task cells, no per-dispatch boxing — so after the first
/// step (worker spawn + scratch growth, covered by warmup) a steady-state
/// tick must allocate exactly as little as the serial path: nothing.
fn sharded_decode_probe() {
    let reg = ArtifactRegistry::open("/nonexistent/artifacts-dir").unwrap();
    reg.set_exec_options(ExecOptions { threads: 2, chunk_size: ExecOptions::DEFAULT_CHUNK });
    let params = ref_lm_demo_params();
    let mut engine = Engine::new(&reg, REF_LM_TAG, &params).unwrap();
    let early = decode_allocs_at(&mut engine, 8);
    let late = decode_allocs_at(&mut engine, 256);
    assert_eq!(early, 0, "sharded Engine::step allocated {early} times per token (want 0)");
    assert_eq!(
        late, 0,
        "sharded Engine::step allocated {late} times per token at pos 256 (want 0)"
    );
}

/// Prefill admissions reuse the executor's persistent `PrefillScratch`
/// (DESIGN.md §13): the first admission grows the working set (plus the
/// engine's one-time prefill machinery), every later same-length
/// admission pays only the handed-off (S, z, logits) outputs — a fixed
/// count that neither grows over admissions nor repeats the first
/// admission's scratch build.
fn prefill_scratch_probe() {
    let reg = ArtifactRegistry::open("/nonexistent/artifacts-dir").unwrap();
    reg.set_exec_options(ExecOptions::serial());
    let params = ref_lm_demo_params();
    let mut engine = Engine::new(&reg, REF_LM_TAG, &params).unwrap();
    assert!(engine.supports_prefill());
    let prompt = [2i32, 4, 6, 8, 10, 12, 14, 16, 3, 5, 7, 9, 11];
    let admit = |engine: &mut Engine, slot: usize| {
        alloc_calls_during(|| {
            let logits = engine.prefill_slot(slot, &prompt).unwrap();
            std::hint::black_box(&logits);
            drop(logits);
        })
    };
    let first = admit(&mut engine, 0);
    let second = admit(&mut engine, 1);
    let third = admit(&mut engine, 2);
    assert!(
        second < first,
        "second admission ({second} allocs) should be cheaper than the first ({first}): \
         the prefill scratch did not persist"
    );
    assert_eq!(
        second, third,
        "admission allocation count must be steady once the scratch is grown \
         (second: {second}, third: {third})"
    );
}

/// The continuous-batching scheduler's decode loop on top of the engine:
/// mid-generation ticks (no admissions, no evictions, no streaming side
/// effects) must allocate nothing — the scheduler's token/sample buffers
/// persist and per-request outputs are pre-reserved at admission. The
/// robustness layer rides along for free: deadline/shed bookkeeping is
/// armed (large budgets, so nothing triggers), the engine's per-step
/// quarantine scan runs, and an explicit `health_check` sweep is added
/// to the measured window — none of it may allocate.
fn scheduler_probe() {
    use hedgehog::serve::{Request, Scheduler, ServePolicy};

    let reg = ArtifactRegistry::open("/nonexistent/artifacts-dir").unwrap();
    reg.set_exec_options(ExecOptions::serial());
    let params = ref_lm_demo_params();
    let mut engine = Engine::new(&reg, REF_LM_TAG, &params).unwrap();
    let cap = engine.batch();
    let policy =
        ServePolicy { deadline_ticks: 10_000, shed_queue_ticks: 10_000, ..ServePolicy::default() };
    let mut sched = Scheduler::with_policy(cap, 2 * cap, policy);
    for id in 0..cap as u64 {
        // max_new large enough that no slot finishes inside the window
        sched.submit(Request { id, prompt: vec![2, 4, 6], max_new: 64, eos: -1 }).unwrap();
    }
    let mut sink = |_id: u64, _tok: i32| {};
    // admission tick (prefill; allocates) + a few decode warmup ticks
    for _ in 0..4 {
        sched.tick(&mut engine, &mut sink).unwrap();
    }
    let allocs = alloc_calls_during(|| {
        for _ in 0..8 {
            sched.tick(&mut engine, &mut sink).unwrap();
            std::hint::black_box(engine.slots.health_check());
        }
    });
    assert_eq!(allocs, 0, "Scheduler::tick allocated {allocs} times over 8 decode ticks (want 0)");
    assert_eq!(sched.active(), cap, "probe window must stay mid-generation");
    assert_eq!(engine.quarantined(), 0, "fault-free probe must not quarantine");
}

#[test]
fn execute_allocations_do_not_scale_with_sequence_length_or_position() {
    kernel_probe();
    decode_probe();
    sharded_decode_probe();
    prefill_scratch_probe();
    scheduler_probe();
}
