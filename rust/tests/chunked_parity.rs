//! Property sweep: the chunked, threaded reference kernels must match the
//! naive row-wise PR-1 oracle (`ExecOptions::naive()`) to ~f32 rounding —
//! across chunk sizes (including 1, a prime, the default, and C >= n so a
//! single block covers the sequence), thread counts, sequence lengths not
//! divisible by the chunk size, and every feature map.
//!
//! Tolerance is 1e-5 *relative* (denominator clamped at 1): the chunked
//! form regroups the same f32 sums, so only rounding differs. The bench
//! harness enforces the same invariant at 1e-4 in CI's bench-smoke job.

use std::path::Path;

use hedgehog::data::Pcg32;
use hedgehog::runtime::backend::Executable as _;
use hedgehog::runtime::reference::kernel_manifest;
use hedgehog::runtime::{Backend, ExecOptions, ReferenceBackend, Tensor};

const REL_TOL: f32 = 1e-5;

fn run(name: &str, shape: &[usize], inputs: &[Tensor], opts: ExecOptions) -> Vec<f32> {
    let backend = ReferenceBackend::with_options(opts);
    let exe = backend.load(Path::new("unused"), &kernel_manifest(name, shape)).unwrap();
    let refs: Vec<&Tensor> = inputs.iter().collect();
    exe.execute(&refs).unwrap().remove(0).as_f32().unwrap().to_vec()
}

fn rand_inputs(seed: u64, shape: &[usize]) -> Vec<Tensor> {
    let mut rng = Pcg32::new(seed);
    let len: usize = shape.iter().product();
    (0..3)
        .map(|_| Tensor::from_f32((0..len).map(|_| rng.normal() * 0.3).collect(), shape))
        .collect()
}

fn assert_close(name: &str, cfg: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{name} {cfg}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        let tol = REL_TOL * b.abs().max(1.0);
        assert!(
            (a - b).abs() <= tol,
            "{name} {cfg}: element {i}: chunked {a} vs naive {b} (|diff| {} > tol {tol})",
            (a - b).abs()
        );
    }
}

/// All kernel families, chunk sizes {1, 7, 64, n}, threads {1, 4},
/// on shapes whose n is deliberately not a multiple of most chunk sizes.
#[test]
fn chunked_matches_naive_oracle_across_chunks_and_threads() {
    // (b, h, n, d): n = 50 (not divisible by 7 or 64), n = 65 (= 64 + 1,
    // exercises the one-row tail chunk), multi-batch multi-head.
    for &shape in &[[1usize, 1, 50, 8], [2, 2, 65, 4], [1, 3, 33, 8]] {
        let n = shape[2];
        let inputs = rand_inputs(0xC0FFEE ^ n as u64, &shape);
        let hedgehog = format!("fig6_hedgehog_n{n}");
        let taylor = format!("fig6_taylor_n{n}");
        for kernel in [
            "kernel_linear_attention",
            "kernel_softmax_attention",
            hedgehog.as_str(),
            taylor.as_str(),
        ] {
            let naive = run(kernel, &shape, &inputs, ExecOptions::naive());
            for chunk in [1usize, 7, 64, n] {
                for threads in [1usize, 4] {
                    let opts = ExecOptions { threads, chunk_size: chunk };
                    let out = run(kernel, &shape, &inputs, opts);
                    assert_close(kernel, &format!("C={chunk} t={threads}"), &out, &naive);
                }
            }
        }
    }
}

/// The decomposition is deterministic for a fixed (threads, chunk)
/// config: two runs must agree bit-for-bit.
#[test]
fn chunked_execution_is_deterministic() {
    let shape = [1usize, 2, 65, 8];
    let inputs = rand_inputs(9, &shape);
    for kernel in ["kernel_linear_attention", "kernel_softmax_attention"] {
        let opts = ExecOptions { threads: 4, chunk_size: 16 };
        let a = run(kernel, &shape, &inputs, opts);
        let b = run(kernel, &shape, &inputs, opts);
        assert_eq!(a, b, "{kernel}: nondeterministic output");
    }
}

/// Pooled execution vs single-threaded (inline, no pool dispatch) vs the
/// naive oracle, across thread counts and chunk sizes, with a full
/// drop/re-create cycle of the backend between rounds. The span
/// decomposition is a pure function of (n, threads, chunk), so for a
/// fixed config the pooled output must be *bit-identical* to the inline
/// output and to a fresh backend's output — this is what proves the pool
/// distributes exactly the planned tasks (and that teardown + respawn is
/// clean: round 2 runs on a brand-new pool after round 1's workers were
/// joined in `Drop`).
#[test]
fn pool_matches_inline_and_naive_across_backend_recreate() {
    let shape = [1usize, 2, 65, 8];
    let inputs = rand_inputs(0xD00D, &shape);
    for kernel in ["kernel_linear_attention", "kernel_softmax_attention", "fig6_hedgehog_n65"] {
        let naive = run(kernel, &shape, &inputs, ExecOptions::naive());
        for chunk in [1usize, 7, 64] {
            for threads in [1usize, 2, 8] {
                let opts = ExecOptions { threads, chunk_size: chunk };
                // threads=1 runs inline on the dispatcher — the pool is
                // never woken. The same opts on a pooled run must agree
                // bit-for-bit because task planning is thread-count (not
                // worker-count) determined.
                let first = run(kernel, &shape, &inputs, opts);
                // `run` constructs a fresh backend per call, so this is a
                // full drop (join workers) + re-create (respawn) cycle.
                let second = run(kernel, &shape, &inputs, opts);
                assert_eq!(
                    first, second,
                    "{kernel} C={chunk} t={threads}: backend re-create changed the output"
                );
                assert_close(kernel, &format!("pool C={chunk} t={threads}"), &first, &naive);
            }
        }
    }
    // Repeated pooled runs of one config must agree bit-for-bit even
    // though task->worker assignment is dynamic: the task -> span -> math
    // mapping is fixed, only who runs each task differs.
    let opts = ExecOptions { threads: 2, chunk_size: 16 };
    let a = run("kernel_linear_attention", &shape, &inputs, opts);
    let b = run("kernel_linear_attention", &shape, &inputs, opts);
    assert_eq!(a, b, "pooled execution is nondeterministic");
}

/// Thread count changes only the span decomposition, never the math:
/// explicit thread counts from 1 to more-threads-than-rows all stay
/// within tolerance of the oracle.
#[test]
fn oversubscribed_threads_stay_correct() {
    let shape = [1usize, 1, 19, 4];
    let inputs = rand_inputs(42, &shape);
    for kernel in ["kernel_linear_attention", "kernel_softmax_attention"] {
        let naive = run(kernel, &shape, &inputs, ExecOptions::naive());
        for threads in [2usize, 8, 32] {
            let opts = ExecOptions { threads, chunk_size: 4 };
            let out = run(kernel, &shape, &inputs, opts);
            assert_close(kernel, &format!("t={threads}"), &out, &naive);
        }
    }
}
