# Entry points shared by CI and local runs (see rust/DESIGN.md §4-5).
#
#   make build        release build (tier-1, no XLA)
#   make test         tier-1 test suite
#   make bench        full kernel + fig6 + decode + train + serve + quality sweep -> BENCH_*.json
#   make bench-smoke  CI short mode: small n, few reps, parity-gated
#   make serve-smoke  short continuous-batching serve load -> BENCH_serve.json
#   make chaos-smoke  seeded fault-injection soak (serve stack) -> BENCH_soak.json
#   make perf-diff    fresh smoke sweep vs the committed BENCH_kernels.json
#                     snapshot (warn-only, >25% tokens/sec regression)
#   make lint-contracts  static contract check: every builtin tag x graph
#                     family manifest vs the derived contract, plus the
#                     mutation self-test and the pool schedule model
#   make lint-unsafe  hermetic SAFETY-comment lint (python, no rustc)
#   make tools-test   unit tests for the python tooling (perf_diff)
#
# `make artifacts` (model-graph export) lives in python/compile and needs
# jax; everything here is hermetic Rust.

.PHONY: build test bench bench-smoke refconv-smoke serve-smoke chaos-smoke perf-diff \
	lint-contracts lint-unsafe tools-test

build:
	cargo build --release

test: build
	cargo test -q

# The kernel harness exits nonzero if any chunked configuration diverges
# from the naive oracle beyond 1e-4 — so `make bench` doubles as a check.
# train_step's reference section is hermetic (builtin ref_lm graphs) and
# emits BENCH_train.json.
bench:
	cargo bench --bench kernel_micro
	cargo bench --bench fig6_scaling
	cargo bench --bench decode_throughput
	cargo bench --bench train_step
	cargo bench --bench serve_load
	cargo bench --bench quality

bench-smoke: refconv-smoke serve-smoke
	BENCH_SMOKE=1 cargo bench --bench kernel_micro
	BENCH_SMOKE=1 cargo bench --bench fig6_scaling
	BENCH_SMOKE=1 cargo bench --bench train_step
	BENCH_SMOKE=1 cargo bench --bench quality

# Continuous-batching serve stack under synthetic Poisson load, per
# builtin tag (chunked prefill + streaming scheduler), short mode.
# Hermetic: reference backend only. Emits BENCH_serve.json at the repo
# root (same convention as the other BENCH_*.json emissions).
serve-smoke:
	BENCH_SMOKE=1 cargo bench --bench serve_load

# Chaos soak (DESIGN.md §11): the serve stack under a seeded, fully
# reproducible fault storm — state/logits corruption, contained worker
# panics, transient executor errors, arrival bursts — asserting that
# every submitted request resolves to exactly one typed outcome and the
# process never aborts. Panic messages in the log are injected faults
# being contained. Emits BENCH_soak.json (robustness census, not a
# latency bench).
chaos-smoke:
	BENCH_SMOKE=1 cargo bench --bench serve_soak

# End-to-end conversion smoke on every builtin config (including the
# 2-layer learnable ref_lm2), artifact-less: teacher train -> per-layer
# distill -> finetune -> eval -> serve on the reference backend. Reports
# land in .bench-fresh/ (gitignored).
refconv-smoke:
	mkdir -p .bench-fresh
	cargo run --release -- expt refconv --scale 0.2 \
		--artifacts /nonexistent-artifacts --results .bench-fresh

# Emit a fresh smoke-mode kernel sweep into .bench-fresh/ (so the
# committed repo-root snapshot is untouched) and compare tokens/sec per
# chunked config against `git show HEAD:BENCH_kernels.json`. Warn-only:
# regressions print a WARNING block, the target still exits 0. Set
# PERF_DIFF_FRESH to reuse an existing emission (CI does this right after
# bench-smoke instead of running the sweep twice).
# Soundness gate (DESIGN.md §12). `lint-contracts` executes no graph:
# the binary statically derives every builtin contract, validates the
# runtime's manifests against it, proves the checker's detection power
# via seeded corruptions, and model-checks the worker-pool protocol over
# bounded interleavings. The same checks also run inside `make test`
# (rust/tests/contract_gate.rs); the binary exists for fast local runs
# and a readable CI log.
lint-contracts:
	cargo run --release --bin contract_check

# Pure-python lints/tests: runnable before (or without) the Rust
# toolchain. CI runs them first — they fail in seconds, not minutes.
lint-unsafe:
	python3 tools/lint_unsafe.py

tools-test:
	python3 tools/test_perf_diff.py

PERF_DIFF_FRESH ?=

perf-diff:
	@if [ -n "$(PERF_DIFF_FRESH)" ]; then \
		python3 tools/perf_diff.py "$(PERF_DIFF_FRESH)"; \
	else \
		mkdir -p .bench-fresh && \
		BENCH_SMOKE=1 BENCH_OUT_DIR=$(CURDIR)/.bench-fresh \
			cargo bench --bench kernel_micro && \
		python3 tools/perf_diff.py .bench-fresh/BENCH_kernels.json; \
	fi
