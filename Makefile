# Entry points shared by CI and local runs (see rust/DESIGN.md §4-5).
#
#   make build        release build (tier-1, no XLA)
#   make test         tier-1 test suite
#   make bench        full kernel + fig6 bench sweep -> BENCH_*.json at repo root
#   make bench-smoke  CI short mode: small n, few reps, parity-gated
#
# `make artifacts` (model-graph export) lives in python/compile and needs
# jax; everything here is hermetic Rust.

.PHONY: build test bench bench-smoke

build:
	cargo build --release

test: build
	cargo test -q

# The kernel harness exits nonzero if any chunked configuration diverges
# from the naive oracle beyond 1e-4 — so `make bench` doubles as a check.
bench:
	cargo bench --bench kernel_micro
	cargo bench --bench fig6_scaling

bench-smoke:
	BENCH_SMOKE=1 cargo bench --bench kernel_micro
	BENCH_SMOKE=1 cargo bench --bench fig6_scaling
