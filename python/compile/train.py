"""Training graphs: losses, AdamW, train/eval steps for every model kind.

Each public `make_*` returns a pure function over explicit pytrees which
aot.py flattens and lowers to one HLO artifact. The optimizer is AdamW
implemented here from scratch (bias-corrected moments, decoupled weight
decay); the learning rate and weight decay are *runtime inputs* so the Rust
orchestrator owns the schedule without recompiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as model_mod

B1, B2, ADAM_EPS = 0.9, 0.999, 1e-8


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def lm_loss(logits, targets, mask):
    """Mean next-token cross-entropy over masked positions.

    logits (B,N,V), targets (B,N) int32, mask (B,N) f32 in {0,1}.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / (mask.sum() + 1e-6)


def class_loss(logits, labels):
    """Mean cross-entropy; logits (B,C), labels (B,) int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def regression_loss(pred, labels):
    """MSE for scalar-regression heads; pred (B,1), labels (B,) f32."""
    return ((pred[:, 0] - labels) ** 2).mean()


def task_loss(cfg, logits, *labels):
    if cfg.kind == "decoder":
        targets, mask = labels
        return lm_loss(logits, targets, mask)
    if cfg.regression:
        return regression_loss(logits, labels[0])
    return class_loss(logits, labels[0])


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, params)


def adamw_update(params, grads, m, v, step, lr, wd):
    """One decoupled-weight-decay Adam step. `step` is the *new* step index
    (1-based) used for bias correction; lr, wd are scalars."""
    b1t = 1.0 - B1 ** step
    b2t = 1.0 - B2 ** step

    def upd(p, g, m_, v_):
        m_new = B1 * m_ + (1.0 - B1) * g
        v_new = B2 * v_ + (1.0 - B2) * g * g
        mhat = m_new / b1t
        vhat = v_new / b2t
        p_new = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + wd * p)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_init(cfg):
    """seed (u32 scalar) -> params pytree."""

    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        return model_mod.init_params(key, cfg)

    return init_fn


def make_train_step(cfg, freeze_pred=None):
    """(params, m, v, step, lr, wd, *batch) -> (params', m', v', step', loss).

    `freeze_pred(path)` -> True freezes that leaf (used for distillation and
    partial finetuning); gradients of frozen leaves are zeroed before AdamW.
    """

    def loss_fn(params, *batch):
        inputs, labels = split_batch(cfg, batch)
        logits = model_mod.forward(params, cfg, *inputs)
        return task_loss(cfg, logits, *labels)

    def step_fn(params, m, v, step, lr, wd, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        if freeze_pred is not None:
            grads = mask_grads(grads, freeze_pred)
        new_step = step + 1
        params, m, v = adamw_update(params, grads, m, v, new_step, lr, wd)
        return params, m, v, new_step, loss

    return step_fn


def make_eval(cfg):
    """(params, *batch) -> (loss, metric) — metric is accuracy for
    classification, MSE again for regression, token-avg NLL for LM."""

    def eval_fn(params, *batch):
        inputs, labels = split_batch(cfg, batch)
        logits = model_mod.forward(params, cfg, *inputs)
        loss = task_loss(cfg, logits, *labels)
        if cfg.kind == "decoder":
            targets, mask = labels
            pred = logits.argmax(-1)
            acc = ((pred == targets) * mask).sum() / (mask.sum() + 1e-6)
        elif cfg.regression:
            acc = loss
        else:
            acc = (logits.argmax(-1) == labels[0]).mean()
        return loss, acc

    return eval_fn


def make_logits(cfg):
    def logits_fn(params, *inputs):
        return model_mod.forward(params, cfg, *inputs)

    return logits_fn


def split_batch(cfg, batch):
    """Split the flat batch tuple into (model_inputs, labels) per kind."""
    if cfg.kind == "decoder":
        tokens, targets, mask = batch
        return (tokens,), (targets, mask)
    if cfg.kind == "vit":
        patches, labels = batch
        return (patches,), (labels,)
    if cfg.pair_input:
        t1, t2, labels = batch
        return (t1, t2), (labels,)
    tokens, labels = batch
    return (tokens,), (labels,)


def batch_specs(cfg, batch_size: int, seq_len: int):
    """ShapeDtypeStructs for one batch, in split_batch order."""
    f32, i32 = jnp.float32, jnp.int32
    if cfg.kind == "decoder":
        return [
            ("tokens", jax.ShapeDtypeStruct((batch_size, seq_len), i32)),
            ("targets", jax.ShapeDtypeStruct((batch_size, seq_len), i32)),
            ("loss_mask", jax.ShapeDtypeStruct((batch_size, seq_len), f32)),
        ]
    if cfg.kind == "vit":
        n_patches = cfg.max_len - 1
        return [
            ("patches", jax.ShapeDtypeStruct((batch_size, n_patches, cfg.patch_dim), f32)),
            ("labels", jax.ShapeDtypeStruct((batch_size,), i32)),
        ]
    specs = [("tokens", jax.ShapeDtypeStruct((batch_size, seq_len), i32))]
    if cfg.pair_input:
        specs.append(("tokens2", jax.ShapeDtypeStruct((batch_size, seq_len), i32)))
    lab_dtype = f32 if cfg.regression else i32
    specs.append(("labels", jax.ShapeDtypeStruct((batch_size,), lab_dtype)))
    return specs


def mask_grads(grads, freeze_pred):
    """Zero gradient leaves whose tree path satisfies freeze_pred(path_str)."""

    def fn(path, g):
        p = path_str(path)
        return jnp.zeros_like(g) if freeze_pred(p) else g

    return jax.tree_util.tree_map_with_path(fn, grads)


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)
