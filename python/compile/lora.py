"""Low-rank adaptation (Hu et al., 2021) for pretrained-conversion (Sec 5.4).

LoRA adapters on the q/k/v/o projections of every layer: W' = W + (alpha/r) A B
with A (d_in, r), B (r, d_out), A gaussian / B zero init so training starts
from the base model. Used for the Table 11 pipeline: distill Hedgehog maps,
then LoRA-finetune the converted model on the summarization task while the
base weights stay frozen.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as model_mod
from . import train as train_mod

TARGETS = ("wq", "wk", "wv", "wo")


def init_lora(key, cfg, rank: int = 8) -> list:
    """One adapter dict per layer: {wq: {a, b}, ...}."""
    adapters = []
    for li in range(cfg.n_layers):
        layer = {}
        for ti, t in enumerate(TARGETS):
            k = jax.random.fold_in(key, li * len(TARGETS) + ti)
            d_in = cfg.d_model if t != "wo" else cfg.heads * cfg.d_head
            d_out = cfg.heads * cfg.d_head if t != "wo" else cfg.d_model
            layer[t] = {
                "a": jax.random.normal(k, (d_in, rank)) * d_in ** -0.5,
                "b": jnp.zeros((rank, d_out)),
            }
        adapters.append(layer)
    return adapters


def merge(params: dict, adapters: list, alpha: float = 16.0, rank: int = 8) -> dict:
    """Return a parameter tree with W' = W + (alpha/r) A B on each target."""
    scale = alpha / rank
    merged = jax.tree_util.tree_map(lambda x: x, params)  # shallow-ish copy
    new_blocks = []
    for layer_p, ad in zip(params["blocks"], adapters):
        mix = dict(layer_p["mix"])
        for t in TARGETS:
            mix[t] = layer_p["mix"][t] + scale * (ad[t]["a"] @ ad[t]["b"])
        new_blocks.append({**layer_p, "mix": mix})
    merged = dict(merged)
    merged["blocks"] = new_blocks
    return merged


def make_lora_train_step(cfg, alpha: float = 16.0, rank: int = 8):
    """(base_params, adapters, m, v, step, lr, wd, *batch) ->
    (adapters', m', v', step', loss). Base weights are frozen inputs."""

    def loss_fn(adapters, base_params, *batch):
        merged = merge(base_params, adapters, alpha, rank)
        inputs, labels = train_mod.split_batch(cfg, batch)
        logits = model_mod.forward(merged, cfg, *inputs)
        return train_mod.task_loss(cfg, logits, *labels)

    def step_fn(base_params, adapters, m, v, step, lr, wd, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(adapters, base_params, *batch)
        new_step = step + 1
        adapters, m, v = train_mod.adamw_update(adapters, grads, m, v, new_step, lr, wd)
        return adapters, m, v, new_step, loss

    return step_fn


def make_lora_eval(cfg, alpha: float = 16.0, rank: int = 8):
    """(base_params, adapters, *batch) -> (loss, metric) on merged weights."""
    ev = train_mod.make_eval(cfg)

    def fn(base_params, adapters, *batch):
        return ev(merge(base_params, adapters, alpha, rank), *batch)

    return fn


def make_lora_logits(cfg, alpha: float = 16.0, rank: int = 8):
    def fn(base_params, adapters, *inputs):
        return model_mod.forward(merge(base_params, adapters, alpha, rank), cfg, *inputs)

    return fn
