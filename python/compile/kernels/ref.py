"""Pure-jnp reference oracles for every L1 kernel.

These are the ground truth the Pallas kernels (and, transitively, every
HLO artifact the Rust runtime executes) are validated against in pytest.
Everything here is written for clarity, not speed: quadratic materialized
attention maps, token-by-token recurrences, explicit masks.

Shapes use the convention:
    q, k : (B, H, N, D)     queries / keys per head
    v    : (B, H, N, Dv)    values per head
    q_f, k_f : (B, H, N, Dp) feature-mapped queries / keys (Dp = feature dim)

`EPS` guards the linear-attention denominator: feature maps are positive, so
the denominator is positive, but it can be tiny for near-zero features.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-6


# ---------------------------------------------------------------------------
# Softmax attention (the teacher / quadratic baseline)
# ---------------------------------------------------------------------------

def softmax_attention_weights(q, k, causal: bool = True, scale: float | None = None):
    """Materialized (B,H,N,N) softmax attention map. Eq. 1 of the paper."""
    d = q.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    scores = jnp.einsum("bhnd,bhmd->bhnm", q, k) * scale
    if causal:
        n = q.shape[-2]
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    return jax.nn.softmax(scores, axis=-1)


def softmax_attention(q, k, v, causal: bool = True, scale: float | None = None):
    """Softmax attention output y_i = sum_j sim(q_i, k_j) v_j."""
    attn = softmax_attention_weights(q, k, causal=causal, scale=scale)
    return jnp.einsum("bhnm,bhmd->bhnd", attn, v)


# ---------------------------------------------------------------------------
# Linear attention (materialized + recurrent forms)
# ---------------------------------------------------------------------------

def linear_attention_weights(q_f, k_f, causal: bool = True):
    """Materialized (B,H,N,N) *normalized* linear attention map (Eq. 2).

    The quadratic form of linear attention: A_ij = phi(q_i).phi(k_j) /
    sum_m phi(q_i).phi(k_m). Used as the student map in distillation and as
    the oracle for the O(n) forms.
    """
    scores = jnp.einsum("bhnp,bhmp->bhnm", q_f, k_f)
    if causal:
        n = q_f.shape[-2]
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        scores = jnp.where(mask, scores, 0.0)
    denom = scores.sum(axis=-1, keepdims=True)
    return scores / (denom + EPS)


def linear_attention(q_f, k_f, v, causal: bool = True):
    """Quadratic-form linear attention output (oracle for the chunked kernel)."""
    attn = linear_attention_weights(q_f, k_f, causal=causal)
    return jnp.einsum("bhnm,bhmd->bhnd", attn, v)


def linear_attention_recurrent(q_f, k_f, v):
    """Token-by-token causal linear attention via the running KV state.

    State per head:  S_t = S_{t-1} + phi(k_t) v_t^T   (Dp, Dv)
                     z_t = z_{t-1} + phi(k_t)         (Dp,)
    Output:          y_t = (phi(q_t) S_t) / (phi(q_t) . z_t)

    Mathematically identical to `linear_attention(..., causal=True)`;
    exercised separately because the chunked Pallas kernel and the Rust
    serving engine both carry this state.
    """
    b, h, n, dp = q_f.shape
    dv = v.shape[-1]

    def step(carry, inputs):
        s, z = carry
        qt, kt, vt = inputs  # (B,H,Dp), (B,H,Dp), (B,H,Dv)
        s = s + jnp.einsum("bhp,bhd->bhpd", kt, vt)
        z = z + kt
        num = jnp.einsum("bhp,bhpd->bhd", qt, s)
        den = jnp.einsum("bhp,bhp->bh", qt, z)
        y = num / (den[..., None] + EPS)
        return (s, z), y

    s0 = jnp.zeros((b, h, dp, dv), q_f.dtype)
    z0 = jnp.zeros((b, h, dp), q_f.dtype)
    xs = (
        jnp.moveaxis(q_f, 2, 0),
        jnp.moveaxis(k_f, 2, 0),
        jnp.moveaxis(v, 2, 0),
    )
    _, ys = jax.lax.scan(step, (s0, z0), xs)
    return jnp.moveaxis(ys, 0, 2)


def linear_attention_noncausal(q_f, k_f, v):
    """Bidirectional linear attention (encoder models): full-sequence state."""
    s = jnp.einsum("bhmp,bhmd->bhpd", k_f, v)
    z = k_f.sum(axis=2)
    num = jnp.einsum("bhnp,bhpd->bhnd", q_f, s)
    den = jnp.einsum("bhnp,bhp->bhn", q_f, z)
    return num / (den[..., None] + EPS)


# ---------------------------------------------------------------------------
# Feature maps (functional references; learnable params passed explicitly)
# ---------------------------------------------------------------------------

def feature_elu(x):
    """1 + ELU  (Katharopoulos et al., 2020)."""
    return 1.0 + jax.nn.elu(x)


def feature_relu(x):
    """ReLU  (T2R without the learned map; Kasai et al., 2021)."""
    return jax.nn.relu(x)


def feature_exp_t(x, t: float = 1.0):
    """Element-wise temperature-scaled exponential phi_t(x) = exp(t*x) (Sec 3.2)."""
    return jnp.exp(t * x)


def feature_performer(x, proj):
    """FAVOR+ positive random features (Choromanski et al., 2020).

    phi(x) = exp(W x - |x|^2 / 2) / sqrt(m),  W ~ N(0, I) rows, shape (D, M).
    """
    m = proj.shape[-1]
    xw = jnp.einsum("bhnd,dm->bhnm", x, proj)
    sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
    return jnp.exp(xw - sq) / jnp.sqrt(m)


def feature_cosformer(x, seq_len: int | None = None):
    """cosFormer (Qin et al., 2022b): ReLU features with cos/sin position
    reweighting. phi(x_i) = [relu(x_i) cos(pi i / 2M), relu(x_i) sin(pi i / 2M)].
    """
    n = x.shape[-2]
    m = n if seq_len is None else seq_len
    idx = jnp.arange(n, dtype=x.dtype)
    theta = jnp.pi * idx / (2.0 * m)
    r = jax.nn.relu(x)
    c = jnp.cos(theta)[None, None, :, None]
    s = jnp.sin(theta)[None, None, :, None]
    return jnp.concatenate([r * c, r * s], axis=-1)


def feature_taylor(x):
    """2nd-degree Taylor features (Sec 4.1): exp(q.k) ~= phi(q).phi(k) with
    phi(x) = [1, x, vec(x x^T)/sqrt(2)]  ->  dim 1 + d + d^2.
    """
    b, h, n, d = x.shape
    ones = jnp.ones((b, h, n, 1), x.dtype)
    outer = jnp.einsum("bhni,bhnj->bhnij", x, x).reshape(b, h, n, d * d)
    return jnp.concatenate([ones, x, outer / jnp.sqrt(2.0)], axis=-1)


def feature_hedgehog(x, w, b=None):
    """Hedgehog spiky MLP feature map (Eq. 3 + Eq. 6, negation mapping).

    phi(x) = [exp(x W + b), exp(-(x W + b))]   with W (H, D, D), b (H, D).
    Per-head trainable map; identity init recovers [exp(x), exp(-x)].
    """
    y = jnp.einsum("bhnd,hde->bhne", x, w)
    if b is not None:
        y = y + b[None, :, None, :]
    return jnp.concatenate([jnp.exp(y), jnp.exp(-y)], axis=-1)


def feature_hedgehog_softmax(x, w, b=None):
    """Numerically-stable Hedgehog variant (Eq. 5): softmax over the MLP
    output dimension, applied to both the positive and negated halves.
    """
    y = jnp.einsum("bhnd,hde->bhne", x, w)
    if b is not None:
        y = y + b[None, :, None, :]
    pos = jax.nn.softmax(y, axis=-1)
    neg = jax.nn.softmax(-y, axis=-1)
    return jnp.concatenate([pos, neg], axis=-1)


def feature_t2r(x, w, b=None):
    """Transformer-to-RNN learned feature map: relu(x W + b) (Kasai 2021)."""
    y = jnp.einsum("bhnd,hde->bhne", x, w)
    if b is not None:
        y = y + b[None, :, None, :]
    return jax.nn.relu(y)


# ---------------------------------------------------------------------------
# Distillation + analysis references
# ---------------------------------------------------------------------------

def distill_soft_xe(pred_attn, true_attn, causal: bool = True):
    """Attention-weight distillation loss (Eq. 4): soft-label cross-entropy
    between the linear (student) and softmax (teacher) attention maps,
    averaged over (B, H, N).
    """
    logp = jnp.log(pred_attn + EPS)
    if causal:
        n = pred_attn.shape[-2]
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        logp = jnp.where(mask, logp, 0.0)
    return -(true_attn * logp).sum(axis=-1).mean()


def attention_entropy(attn):
    """Mean Shannon entropy (nats) of each row of an attention map (Fig 2/4)."""
    h = -(attn * jnp.log(attn + EPS)).sum(axis=-1)
    return h.mean()


def attention_kl(true_attn, pred_attn):
    """Mean KL(true || pred) over rows of the attention maps (Tables 4/5/14)."""
    kl = (true_attn * (jnp.log(true_attn + EPS) - jnp.log(pred_attn + EPS))).sum(-1)
    return kl.mean()
