"""Feature-map zoo: every phi() the paper compares, behind one registry.

Each entry knows its feature dimension, whether it carries trainable
parameters, and how to apply itself to per-head (B, H, N, D) tensors.
The L2 models select a map by name; the distillation and analysis graphs
iterate the registry. All maps are plain differentiable jnp (they are cheap
elementwise/matmul prologues); the O(N) attention itself is the Pallas
kernel in linear_attention.py.

Scaling convention: softmax attention uses scores q.k/sqrt(d) (Eq. 1). For
a like-for-like comparison every feature map receives queries and keys
pre-scaled by d**-0.25 each (so phi(q).phi(k) sees the same temperature the
softmax teacher does). The models apply this scaling before calling phi.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import ref


@dataclasses.dataclass(frozen=True)
class FeatureMap:
    """A named feature map phi: R^d -> R^{feature_dim(d)}."""

    name: str
    feature_dim: Callable[[int], int]
    init: Callable[[Any, int, int], dict]  # (key, heads, d) -> params
    apply: Callable[[dict, jnp.ndarray], jnp.ndarray]
    trainable: bool
    spiky: bool      # paper Table 2 property column
    monotonic: bool  # paper Table 2 property column


def _no_params(_key, _heads, _d):
    return {}


def _linear_map_params(key, heads, d, identity_init=True):
    """Per-head (H, D, D) weight + (H, D) bias, identity-initialized (A.2)."""
    if identity_init:
        w = jnp.tile(jnp.eye(d)[None], (heads, 1, 1))
    else:
        w = jax.random.normal(key, (heads, d, d)) * (d ** -0.5)
    return {"w": w, "b": jnp.zeros((heads, d))}


def _performer_params(key, heads, d):
    # Shared Gaussian projection (redrawn per model init, fixed thereafter).
    return {"proj": jax.random.normal(key, (d, d))}


REGISTRY: dict[str, FeatureMap] = {}


def _register(fm: FeatureMap) -> FeatureMap:
    REGISTRY[fm.name] = fm
    return fm


SOFTMAX = "softmax"  # sentinel: not a feature map; models dispatch specially

ELU = _register(
    FeatureMap(
        "elu",
        feature_dim=lambda d: d,
        init=_no_params,
        apply=lambda p, x: ref.feature_elu(x),
        trainable=False,
        spiky=False,
        monotonic=False,
    )
)

RELU = _register(
    FeatureMap(
        "relu",
        feature_dim=lambda d: d,
        init=_no_params,
        apply=lambda p, x: ref.feature_relu(x),
        trainable=False,
        spiky=False,
        monotonic=False,
    )
)

EXP_T1 = _register(
    FeatureMap(
        "exp_t1",
        feature_dim=lambda d: d,
        init=_no_params,
        apply=lambda p, x: ref.feature_exp_t(x, 1.0),
        trainable=False,
        spiky=False,
        monotonic=False,
    )
)

EXP_T2 = _register(
    FeatureMap(
        "exp_t2",
        feature_dim=lambda d: d,
        init=_no_params,
        apply=lambda p, x: ref.feature_exp_t(x, 2.0),
        trainable=False,
        spiky=True,
        monotonic=False,
    )
)

PERFORMER = _register(
    FeatureMap(
        "performer",
        feature_dim=lambda d: d,
        init=_performer_params,
        apply=lambda p, x: ref.feature_performer(x, p["proj"]),
        trainable=False,  # projection is fixed after init (FAVOR+)
        spiky=False,
        monotonic=False,
    )
)

COSFORMER = _register(
    FeatureMap(
        "cosformer",
        feature_dim=lambda d: 2 * d,
        init=_no_params,
        apply=lambda p, x: ref.feature_cosformer(x),
        trainable=False,
        spiky=False,
        monotonic=False,
    )
)

TAYLOR = _register(
    FeatureMap(
        "taylor",
        feature_dim=lambda d: 1 + d + d * d,
        init=_no_params,
        apply=lambda p, x: ref.feature_taylor(x),
        trainable=False,
        spiky=True,
        monotonic=True,
    )
)

HEDGEHOG = _register(
    FeatureMap(
        "hedgehog",
        feature_dim=lambda d: 2 * d,
        init=_linear_map_params,
        apply=lambda p, x: ref.feature_hedgehog(x, p["w"], p["b"]),
        trainable=True,
        spiky=True,
        monotonic=True,
    )
)

HEDGEHOG_SM = _register(
    FeatureMap(
        "hedgehog_sm",
        feature_dim=lambda d: 2 * d,
        init=_linear_map_params,
        apply=lambda p, x: ref.feature_hedgehog_softmax(x, p["w"], p["b"]),
        trainable=True,
        spiky=True,
        monotonic=True,
    )
)

T2R = _register(
    FeatureMap(
        "t2r",
        feature_dim=lambda d: d,
        init=_linear_map_params,
        apply=lambda p, x: ref.feature_t2r(x, p["w"], p["b"]),
        trainable=True,
        spiky=False,
        monotonic=False,
    )
)


def get(name: str) -> FeatureMap:
    """Look up a feature map; raises KeyError with the known names."""
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown feature map {name!r}; known: {sorted(REGISTRY)}")


def init_params(name: str, key, heads: int, d: int) -> dict:
    return get(name).init(key, heads, d)


def apply(name: str, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return get(name).apply(params, x)


ALL_LINEAR = sorted(REGISTRY)
