"""L1 Pallas kernel: blockwise (flash-style) causal softmax attention.

The quadratic baseline / distillation teacher, written with the online
softmax recurrence so the (N x N) score matrix is never materialized:

    m_i   <- max(m_i, rowmax(S_block))
    l_i   <- l_i * exp(m_old - m_i) + rowsum(exp(S_block - m_i))
    acc_i <- acc_i * exp(m_old - m_i) + exp(S_block - m_i) V_block

Grid is (B*H, Nq/C, Nk/C) with the k-block axis innermost; the running
(m, l, acc) statistics persist in VMEM scratch across k-blocks and the
normalized output is written on the final k-block. Fully-masked causal
blocks (k-block start > q-block end) contribute nothing — on real TPU they
would be skipped by the grid; under interpret=True they are computed and
masked, which only costs CPU-test time.

Forward-only: training graphs that need a differentiable softmax baseline
use the jnp reference (ref.softmax_attention) — the quadratic baseline is
not the paper's hot path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, chunk, nk, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (C, D)
    k = k_ref[0]  # (C, D)
    v = v_ref[0]  # (C, Dv)

    s = jnp.dot(q, k.T) * scale  # (C, C)
    rows = qi * chunk + jnp.arange(chunk)[:, None]
    cols = ki * chunk + jnp.arange(chunk)[None, :]
    s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_ref[...]                   # (C, 1)
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)                # (C, C)
    corr = jnp.exp(m_prev - m_new)        # (C, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / l_ref[...]


def softmax_attention_pallas(q, k, v, chunk: int = 64, scale: float | None = None):
    """Causal softmax attention via the blockwise Pallas kernel.

    Args:
      q, k: (B, H, N, D). v: (B, H, N, Dv). N divisible by `chunk`.
      scale: score scale; defaults to 1/sqrt(D) (Eq. 1).
    Returns:
      (B, H, N, Dv), matching ref.softmax_attention to fp32 tolerance.
    """
    b, h, n, d = q.shape
    dv = v.shape[-1]
    scale = (d ** -0.5) if scale is None else scale
    bh = b * h
    nk = n // chunk

    out = pl.pallas_call(
        functools.partial(_flash_kernel, chunk=chunk, nk=nk, scale=scale),
        grid=(bh, nk, nk),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda i, j, kk: (i, j, 0)),
            pl.BlockSpec((1, chunk, d), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, j, kk: (i, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda i, j, kk: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((chunk, 1), q.dtype),
            pltpu.VMEM((chunk, 1), q.dtype),
            pltpu.VMEM((chunk, dv), q.dtype),
        ],
        interpret=True,
    )(q.reshape(bh, n, d), k.reshape(bh, n, d), v.reshape(bh, n, dv))
    return out.reshape(b, h, n, dv)
