"""L1 Pallas kernels: chunked causal linear attention (the Hedgehog hot path).

Computes, for feature-mapped queries/keys `q_f, k_f` (B, H, N, Dp) and values
`v` (B, H, N, Dv):

    y_i = ( phi(q_i) . sum_{j<=i} phi(k_j) v_j^T ) / ( phi(q_i) . sum_{j<=i} phi(k_j) )

in O(N * Dp * Dv) time by carrying the running KV state

    S in R^{Dp x Dv},   z in R^{Dp}

across sequence chunks of length CHUNK. Within a chunk, the causal part is a
small (CHUNK x CHUNK) masked matmul; across chunks the state is updated with
one (Dp x CHUNK) @ (CHUNK x Dv) contraction — both MXU-systolic-array-shaped.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the state lives in
VMEM scratch for the whole row of the grid; q/k/v stream HBM->VMEM one chunk
at a time via BlockSpec. This is the TPU-native expression of what the
paper's CUDA implementations do with threadblock tiling.

A hand-derived custom VJP makes the kernel differentiable (pallas_call has
no autodiff rule), so the same kernel sits inside the L2 training graphs.
Backward math (u_i = dy_i / den_i, a_i = -(dy_i . y_i) / den_i):

    dqf_i = S_i u_i + a_i z_i            (forward-direction scan, recompute S)
    dkf_j = T_j v_j + r_j                (reverse scan: T_j = sum_{i>=j} qf_i u_i^T,
    dv_j  = T_j^T kf_j                               r_j = sum_{i>=j} a_i qf_i)

All kernels run interpret=True (CPU PJRT cannot execute Mosaic custom-calls);
structure, not interpret-mode wallclock, is the optimization target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6
DEFAULT_CHUNK = 64


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(qf_ref, kf_ref, v_ref, o_ref, den_ref, s_ref, z_ref, *, chunk):
    """One (batch*head, chunk) grid step of the chunked forward pass."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    qf = qf_ref[0]  # (C, Dp)
    kf = kf_ref[0]  # (C, Dp)
    v = v_ref[0]    # (C, Dv)

    # Intra-chunk causal scores (C, C), inclusive lower triangle.
    scores = jnp.dot(qf, kf.T)
    mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    scores = jnp.where(mask, scores, 0.0)

    num = jnp.dot(qf, s_ref[...]) + jnp.dot(scores, v)           # (C, Dv)
    den = jnp.dot(qf, z_ref[...]) + scores.sum(-1, keepdims=True)  # (C, 1)
    den = den + EPS

    o_ref[0] = num / den
    den_ref[0] = den

    # Inter-chunk state update (runs after outputs: state holds prefix < chunk).
    s_ref[...] += jnp.dot(kf.T, v)
    z_ref[...] += kf.sum(0)[:, None]


def _fwd(qf, kf, v, chunk):
    b, h, n, dp = qf.shape
    dv = v.shape[-1]
    bh = b * h
    qf2 = qf.reshape(bh, n, dp)
    kf2 = kf.reshape(bh, n, dp)
    v2 = v.reshape(bh, n, dv)

    grid = (bh, n // chunk)
    out, den = pl.pallas_call(
        functools.partial(_fwd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, dv), qf.dtype),
            jax.ShapeDtypeStruct((bh, n, 1), qf.dtype),
        ],
        scratch_shapes=_tpu_scratch(qf.dtype, dp, dv),
        interpret=True,
    )(qf2, kf2, v2)
    return out.reshape(b, h, n, dv), den.reshape(b, h, n, 1)


def _tpu_scratch(dtype, dp, dv):
    from jax.experimental.pallas import tpu as pltpu

    return [pltpu.VMEM((dp, dv), dtype), pltpu.VMEM((dp, 1), dtype)]


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(qf_ref, kf_ref, v_ref, u_ref, a_ref, dqf_ref, s_ref, z_ref, *, chunk):
    """Forward-direction scan computing dqf; recomputes the prefix state."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    qf = qf_ref[0]
    kf = kf_ref[0]
    v = v_ref[0]
    u = u_ref[0]    # (C, Dv) = dy / den
    a = a_ref[0]    # (C, 1)  = -(dy . y) / den

    # Intra-chunk (inclusive) causal contributions.
    uv = jnp.dot(u, v.T)  # (C, C): (v_j . u_i) at [i, j]
    mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    uv = jnp.where(mask, uv, 0.0)
    # dqf_i = S_{<c} u_i + sum_{j<=i in chunk} (v_j.u_i) kf_j  + a_i * z_i
    dqf = jnp.dot(u, s_ref[...].T) + jnp.dot(uv, kf)
    zcum = z_ref[...][:, 0][None, :] + jnp.cumsum(kf, axis=0)  # (C, Dp) z_i
    dqf = dqf + a * zcum
    dqf_ref[0] = dqf

    s_ref[...] += jnp.dot(kf.T, v)
    z_ref[...] += kf.sum(0)[:, None]


def _bwd_dkv_kernel(qf_ref, kf_ref, v_ref, u_ref, a_ref, dkf_ref, dv_ref, t_ref, r_ref, *, chunk, nchunks):
    """Reverse-direction scan computing dkf and dv via suffix states T, r."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        t_ref[...] = jnp.zeros_like(t_ref)
        r_ref[...] = jnp.zeros_like(r_ref)

    qf = qf_ref[0]
    kf = kf_ref[0]
    v = v_ref[0]
    u = u_ref[0]
    a = a_ref[0]

    # Suffix-inclusive within the chunk: i >= j (upper triangle inclusive).
    uv = jnp.dot(u, v.T)  # [i, j] = v_j . u_i
    mask_ge = jnp.triu(jnp.ones((chunk, chunk), dtype=bool)).T  # [i, j] True when i >= j
    # dkf_j = sum_{i >= j} (v_j.u_i) qf_i  +  T_{>c} v_j  +  sum_{i>=j} a_i qf_i + r_{>c}
    uv_ge = jnp.where(mask_ge, uv, 0.0)  # (C, C)
    dkf = jnp.dot(uv_ge.T, qf) + jnp.dot(v, t_ref[...].T)
    # reverse-cumulative sum of a_i qf_i within chunk (inclusive)
    aq = a * qf  # (C, Dp)
    rev = jnp.cumsum(aq[::-1], axis=0)[::-1]  # (C, Dp): sum_{i>=j within chunk}
    dkf = dkf + rev + r_ref[...][:, 0][None, :]
    dkf_ref[0] = dkf

    # dv_j = sum_{i>=j} (qf_i.kf_j) u_i = intra + T_{>c}^T kf_j
    qk = jnp.dot(qf, kf.T)  # [i, j]
    qk_ge = jnp.where(mask_ge, qk, 0.0)
    dv = jnp.dot(qk_ge.T, u) + jnp.dot(kf, t_ref[...])
    dv_ref[0] = dv

    t_ref[...] += jnp.dot(qf.T, u)
    r_ref[...] += jnp.dot(qf.T, a)


def _bwd(chunk, res, dy):
    qf, kf, v, y, den = res
    b, h, n, dp = qf.shape
    dv_dim = v.shape[-1]
    bh = b * h

    u = dy / den                                        # (B,H,N,Dv)
    a = -(dy * y).sum(-1, keepdims=True) / den          # (B,H,N,1)

    qf2 = qf.reshape(bh, n, dp)
    kf2 = kf.reshape(bh, n, dp)
    v2 = v.reshape(bh, n, dv_dim)
    u2 = u.reshape(bh, n, dv_dim)
    a2 = a.reshape(bh, n, 1)

    nchunks = n // chunk
    spec_p = pl.BlockSpec((1, chunk, dp), lambda i, j: (i, j, 0))
    spec_v = pl.BlockSpec((1, chunk, dv_dim), lambda i, j: (i, j, 0))
    spec_1 = pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0))

    dqf = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, chunk=chunk),
        grid=(bh, nchunks),
        in_specs=[spec_p, spec_p, spec_v, spec_v, spec_1],
        out_specs=spec_p,
        out_shape=jax.ShapeDtypeStruct((bh, n, dp), qf.dtype),
        scratch_shapes=_tpu_scratch(qf.dtype, dp, dv_dim),
        interpret=True,
    )(qf2, kf2, v2, u2, a2)

    # Reverse scan: flip the chunk axis via the index map.
    rev = lambda i, j: (i, nchunks - 1 - j, 0)
    spec_pr = pl.BlockSpec((1, chunk, dp), rev)
    spec_vr = pl.BlockSpec((1, chunk, dv_dim), rev)
    spec_1r = pl.BlockSpec((1, chunk, 1), rev)

    dkf, dvv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, chunk=chunk, nchunks=nchunks),
        grid=(bh, nchunks),
        in_specs=[spec_pr, spec_pr, spec_vr, spec_vr, spec_1r],
        out_specs=[spec_pr, spec_vr],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, dp), qf.dtype),
            jax.ShapeDtypeStruct((bh, n, dv_dim), qf.dtype),
        ],
        scratch_shapes=_tpu_scratch(qf.dtype, dp, dv_dim),
        interpret=True,
    )(qf2, kf2, v2, u2, a2)

    return (
        dqf.reshape(b, h, n, dp),
        dkf.reshape(b, h, n, dp),
        dvv.reshape(b, h, n, dv_dim),
    )


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear_attention_pallas(qf, kf, v, chunk: int = DEFAULT_CHUNK):
    """Causal normalized linear attention, O(N) chunked Pallas kernel.

    Args:
      qf, kf: feature-mapped queries/keys (B, H, N, Dp); must be >= 0.
      v: values (B, H, N, Dv).
      chunk: sequence chunk length; N must be divisible by it (pad upstream).
    Returns:
      (B, H, N, Dv) attention outputs, matching ref.linear_attention.
    """
    out, _ = _fwd(qf, kf, v, chunk)
    return out


def _vjp_fwd(qf, kf, v, chunk):
    out, den = _fwd(qf, kf, v, chunk)
    return out, (qf, kf, v, out, den)


linear_attention_pallas.defvjp(_vjp_fwd, _bwd)


def linear_attention_scan(qf, kf, v, chunk: int = DEFAULT_CHUNK):
    """Chunked causal linear attention as a pure-jnp lax.scan.

    Same O(N) math and chunking as the Pallas kernel, but expressed with
    lax.scan so it stays compact inside large AOT-lowered training graphs
    (interpret-mode pallas unrolls its grid into the jaxpr; see DESIGN.md).
    Fully differentiable through native jax autodiff.
    """
    b, h, n, dp = qf.shape
    dv = v.shape[-1]
    nchunks = n // chunk
    qc = qf.reshape(b, h, nchunks, chunk, dp)
    kc = kf.reshape(b, h, nchunks, chunk, dp)
    vc = v.reshape(b, h, nchunks, chunk, dv)
    mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))

    def step(carry, inp):
        s, z = carry  # (B,H,Dp,Dv), (B,H,Dp)
        qb, kb, vb = inp
        scores = jnp.einsum("bhcp,bhdp->bhcd", qb, kb)
        scores = jnp.where(mask, scores, 0.0)
        num = jnp.einsum("bhcp,bhpd->bhcd", qb, s) + jnp.einsum(
            "bhcd,bhde->bhce", scores, vb
        )
        den = jnp.einsum("bhcp,bhp->bhc", qb, z) + scores.sum(-1)
        y = num / (den[..., None] + EPS)
        s = s + jnp.einsum("bhcp,bhcd->bhpd", kb, vb)
        z = z + kb.sum(axis=2)
        return (s, z), y

    s0 = jnp.zeros((b, h, dp, dv), qf.dtype)
    z0 = jnp.zeros((b, h, dp), qf.dtype)
    xs = (
        jnp.moveaxis(qc, 2, 0),
        jnp.moveaxis(kc, 2, 0),
        jnp.moveaxis(vc, 2, 0),
    )
    _, ys = jax.lax.scan(step, (s0, z0), xs)  # (nchunks, B, H, chunk, Dv)
    return jnp.moveaxis(ys, 0, 2).reshape(b, h, n, dv)


def linear_attention_decode_step(s, z, qf_t, kf_t, v_t):
    """Single-token recurrent decode update (the serving engine hot path).

    Args:
      s: (B, H, Dp, Dv) running KV state.  z: (B, H, Dp) running key sum.
      qf_t, kf_t: (B, H, Dp) current-token features.  v_t: (B, H, Dv).
    Returns:
      (s', z', y_t) with y_t (B, H, Dv).
    """
    s = s + jnp.einsum("bhp,bhd->bhpd", kf_t, v_t)
    z = z + kf_t
    num = jnp.einsum("bhp,bhpd->bhd", qf_t, s)
    den = jnp.einsum("bhp,bhp->bh", qf_t, z)
    return s, z, num / (den[..., None] + EPS)
