"""Model + experiment configuration registry — the single source of truth
shared between the Python compile path and the Rust runtime (via artifact
manifests).

Sizes are scaled to the testbed (single-core CPU PJRT): each family keeps
the paper's *structure* (layers of pre-LN attention+MLP, per-head feature
maps, the same train/distill/finetune pipelines) at widths where the full
experiment grid runs in minutes. The `e2e_*` family scales up for the
end-to-end example (`examples/train_e2e.rs`).

Batch shapes live here too so Rust and Python agree by construction.
"""

from __future__ import annotations

import dataclasses

from .model import ModelConfig


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """Batch geometry attached to a model family."""

    batch_size: int
    seq_len: int


# family name -> (base ModelConfig, TrainSpec)
FAMILIES: dict[str, tuple[ModelConfig, TrainSpec]] = {}


def _fam(cfg: ModelConfig, batch: int, seq: int):
    FAMILIES[cfg.name] = (cfg, TrainSpec(batch, seq))
    return cfg


# --- Associative recall (Sec 3.2, Figs 2/4, Tables 2/3) ---------------------
# Paper: vocab 40, seq 128, 4 layers. Scaled: vocab 32, seq 64, 2 layers.
AR = _fam(
    ModelConfig(
        name="ar", kind="decoder", vocab=34, n_layers=2, heads=2,
        d_head=16, d_model=64, max_len=64,
    ),
    batch=32, seq=64,
)

# --- GLUE-like encoder (Tables 1/8/15, Figs 3/5/7/9) ------------------------
# One encoder family; per-task heads (num_classes / regression) via variants.
GLUE = _fam(
    ModelConfig(
        name="glue", kind="encoder", vocab=64, n_layers=2, heads=2,
        d_head=16, d_model=64, max_len=64, num_classes=2,
    ),
    batch=16, seq=64,
)

# --- Language modeling (Tables 7/10; the WT-103 stand-in) --------------------
LM = _fam(
    ModelConfig(
        name="lm", kind="decoder", vocab=256, n_layers=2, heads=2,
        d_head=16, d_model=64, max_len=128,
    ),
    batch=8, seq=128,
)

# --- LRA-like long-range tasks (Table 6/13) ----------------------------------
LRA_LISTOPS = _fam(
    ModelConfig(
        name="lra_listops", kind="encoder", vocab=20, n_layers=2, heads=2,
        d_head=16, d_model=64, max_len=128, num_classes=10,
    ),
    batch=16, seq=128,
)
LRA_TEXT = _fam(
    ModelConfig(
        name="lra_text", kind="encoder", vocab=100, n_layers=2, heads=2,
        d_head=16, d_model=64, max_len=256, num_classes=2,
    ),
    batch=8, seq=256,
)
LRA_RETRIEVAL = _fam(
    ModelConfig(
        name="lra_retrieval", kind="encoder", vocab=64, n_layers=2, heads=2,
        d_head=16, d_model=64, max_len=128, num_classes=2, pair_input=True,
    ),
    batch=8, seq=128,
)
LRA_IMAGE = _fam(
    ModelConfig(
        name="lra_image", kind="encoder", vocab=64, n_layers=2, heads=2,
        d_head=16, d_model=64, max_len=256, num_classes=10,
    ),
    batch=8, seq=256,
)
LRA_PATHFINDER = _fam(
    ModelConfig(
        name="lra_pathfinder", kind="encoder", vocab=4, n_layers=2, heads=2,
        d_head=16, d_model=64, max_len=256, num_classes=2,
    ),
    batch=8, seq=256,
)

# --- ViT (Table 9) -----------------------------------------------------------
VIT = _fam(
    ModelConfig(
        name="vit", kind="vit", vocab=0, n_layers=2, heads=2, d_head=16,
        d_model=64, max_len=17, num_classes=10, patch_dim=16,
    ),
    batch=16, seq=16,  # 16 patches (4x4 grid of 4x4 patches of a 16x16 image)
)

# --- Summarization decoder (Table 11; SAMSum stand-in) ------------------------
SUM = _fam(
    ModelConfig(
        name="sum", kind="decoder", vocab=256, n_layers=2, heads=2,
        d_head=16, d_model=64, max_len=192,
    ),
    batch=8, seq=192,
)

# --- End-to-end example drivers ------------------------------------------------
E2E_SMALL = _fam(
    ModelConfig(
        name="e2e_small", kind="decoder", vocab=512, n_layers=4, heads=4,
        d_head=16, d_model=128, max_len=128,
    ),
    batch=8, seq=128,
)
E2E_MEDIUM = _fam(
    ModelConfig(
        name="e2e_medium", kind="decoder", vocab=1024, n_layers=6, heads=8,
        d_head=32, d_model=256, max_len=256,
    ),
    batch=4, seq=256,
)

# GLUE task table: task -> (num_classes, regression). Pair tasks are encoded
# as single concatenated sequences with a separator token (documented
# substitution; keeps one encoder family for the whole table).
GLUE_TASKS: dict[str, tuple[int, bool]] = {
    "cola": (2, False),
    "sst2": (2, False),
    "mrpc": (2, False),
    "stsb": (1, True),
    "qqp": (2, False),
    "mnli": (3, False),
    "qnli": (2, False),
    "rte": (2, False),
}

# Feature-map variants exercised by the experiment grid.
PRIOR_MAPS = ["elu", "relu", "performer", "cosformer", "exp_t1", "exp_t2"]
LEARNED_MAPS = ["hedgehog", "t2r"]
ALL_MAPS = PRIOR_MAPS + ["taylor"] + LEARNED_MAPS


def family(name: str) -> tuple[ModelConfig, TrainSpec]:
    return FAMILIES[name]


def variant(name: str, attn: str, **overrides) -> ModelConfig:
    cfg, _spec = FAMILIES[name]
    return cfg.replace(attn=attn, **overrides)
