"""Analysis graphs for the paper's diagnostic figures.

Exported per model config so the Rust experiment runner can measure:

  * `attn_stats`     — teacher/student attention entropies + KL (Figs 2, 4,
                       7, 8; Tables 4, 5, 14).
  * `mono_probe`     — (dot-product, teacher weight, student weight)
                       triples from layer-0/head-0 (Fig 3/5 monotonicity;
                       Rust computes Spearman rho over them).
  * `attn_dump`      — full (N, N) teacher and student maps for one
                       layer/head (the qualitative weight visualizations,
                       Figs 7-20; written to disk by the runner).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import model as model_mod
from .kernels import feature_maps, ref


def _layer_maps(params, cfg, *inputs):
    """Per-layer (teacher softmax map, student map) over one batch."""
    teacher_cfg = cfg.replace(attn="softmax")
    if cfg.kind == "vit":
        _, hiddens = model_mod.collect_hidden(params, teacher_cfg, None, patches=inputs[0])
    else:
        _, hiddens = model_mod.collect_hidden(params, teacher_cfg, inputs[0])
    out = []
    for layer_p, h in zip(params["blocks"], hiddens):
        q, k = attn_mod.qk_heads(layer_p["mix"], cfg, h)
        teacher = ref.softmax_attention_weights(q, k, causal=cfg.causal, scale=1.0)
        if cfg.attn == "softmax":
            student = teacher
        else:
            fm_params = layer_p["mix"].get("fm", {})
            if cfg.attn == "performer":
                proj = jax.random.normal(
                    jax.random.PRNGKey(1234 + cfg.d_head), (cfg.d_head, cfg.d_head)
                )
                qf, kf = ref.feature_performer(q, proj), ref.feature_performer(k, proj)
            else:
                qf = feature_maps.apply(cfg.attn, fm_params, q)
                kf = feature_maps.apply(cfg.attn, fm_params, k)
            student = ref.linear_attention_weights(qf, kf, causal=cfg.causal)
        out.append((teacher, student, q, k))
    return out


def _masked_row_entropy(attn, causal):
    h = -(attn * jnp.log(attn + ref.EPS)).sum(-1)
    return h.mean()


def make_attn_stats(cfg):
    """(params, *inputs) -> (teacher_entropy, student_entropy, kl)."""

    def fn(params, *inputs):
        maps = _layer_maps(params, cfg, *inputs)
        te, se, kl = 0.0, 0.0, 0.0
        n = maps[0][0].shape[-1]
        tri = jnp.tril(jnp.ones((n, n), dtype=bool)) if cfg.causal else None
        for teacher, student, _, _ in maps:
            te = te + _masked_row_entropy(teacher, cfg.causal)
            se = se + _masked_row_entropy(student, cfg.causal)
            terms = teacher * (jnp.log(teacher + ref.EPS) - jnp.log(student + ref.EPS))
            if tri is not None:
                terms = jnp.where(tri, terms, 0.0)
            kl = kl + terms.sum(-1).mean()
        L = len(maps)
        return te / L, se / L, kl / L

    return fn


def make_mono_probe(cfg):
    """(params, *inputs) -> (dots, teacher_w, student_w), each (B*N*N,).

    Flattened (q_i . k_j, teacher A_ij, student A_ij) triples from layer 0,
    head 0 — enough to draw Fig 3 and compute Spearman monotonicity.
    """

    def fn(params, *inputs):
        maps = _layer_maps(params, cfg, *inputs)
        teacher, student, q, k = maps[0]
        dots = jnp.einsum("bnd,bmd->bnm", q[:, 0], k[:, 0])
        t = teacher[:, 0]
        s = student[:, 0]
        if cfg.causal:
            n = dots.shape[-1]
            tri = jnp.tril(jnp.ones((n, n), dtype=bool), k=-0)
            # keep strictly valid positions; invalid marked with NaN for Rust to drop
            dots = jnp.where(tri, dots, jnp.nan)
        return dots.reshape(-1), t.reshape(-1), s.reshape(-1)

    return fn


def make_attn_dump(cfg, layer: int = 0, head: int = 0):
    """(params, *inputs) -> (teacher_map, student_map) for one layer/head,
    shape (B, N, N) each."""

    def fn(params, *inputs):
        maps = _layer_maps(params, cfg, *inputs)
        teacher, student, _, _ = maps[min(layer, len(maps) - 1)]
        return teacher[:, head], student[:, head]

    return fn
