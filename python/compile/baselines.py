"""Subquadratic baseline sequence mixers the paper compares against.

Drop-in replacements for the attention mixer inside a Transformer block
(Table 7: AFT, Table 10: Hybrid H3, Hyena). Implemented from scratch,
scaled to this repo's model sizes:

  * `aft`   — AFT-simple (Zhai et al., 2021): gated causal exponential
              moving pool over values.
  * `h3`    — H3-lite (Fu et al., 2023): shift-SSM + diagonal-SSM with
              multiplicative q/k gating (the Hungry-Hungry-Hippos recipe
              with diagonal state and per-channel decays).
  * `hyena` — Hyena-lite (Poli et al., 2023): order-2 gated implicit long
              convolution; filters are an MLP of sinusoidal positional
              features with exponential decay windowing, applied via FFT.

All operate on (B, N, D) hidden states and are causal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# AFT-simple
# ---------------------------------------------------------------------------

def init_aft(key, cfg) -> dict:
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    return {
        "wq": jax.random.normal(k1, (d, d)) * std,
        "wk": jax.random.normal(k2, (d, d)) * std,
        "wv": jax.random.normal(k3, (d, d)) * std,
        "wo": jax.random.normal(k4, (d, d)) * std,
    }


def aft_mixer(params, cfg, x):
    """AFT-simple: y_t = sigmoid(q_t) * cumsum(exp(k)*v)_t / cumsum(exp(k))_t."""
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    k = k - jax.lax.stop_gradient(k.max(axis=1, keepdims=True))  # stability
    ek = jnp.exp(k)
    num = jnp.cumsum(ek * v, axis=1)
    den = jnp.cumsum(ek, axis=1) + 1e-6
    y = jax.nn.sigmoid(q) * (num / den)
    return y @ params["wo"]


# ---------------------------------------------------------------------------
# H3-lite
# ---------------------------------------------------------------------------

def init_h3(key, cfg) -> dict:
    d = cfg.d_model
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    std = d ** -0.5
    return {
        "wq": jax.random.normal(k1, (d, d)) * std,
        "wk": jax.random.normal(k2, (d, d)) * std,
        "wv": jax.random.normal(k3, (d, d)) * std,
        "wo": jax.random.normal(k4, (d, d)) * std,
        # per-channel decay in (0,1) via sigmoid; init near 0.9..0.99
        "log_decay": jax.random.uniform(k5, (d,), minval=2.0, maxval=4.0),
    }


def _diag_ssm(x, decay):
    """s_t = a * s_{t-1} + x_t per channel, via parallel associative scan."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    n = x.shape[1]
    a = jnp.broadcast_to(decay[None, None, :], x.shape)
    _, s = jax.lax.associative_scan(combine, (a, x), axis=1)
    return s


def h3_mixer(params, cfg, x):
    """H3-lite: q * diag-SSM(k * shift(v)) with learned per-channel decays."""
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    # shift-SSM: v delayed by one step (the 'shift' memory of H3)
    v_shift = jnp.pad(v, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    decay = jax.nn.sigmoid(params["log_decay"])
    s = _diag_ssm(k * v_shift, decay)
    return (q * s) @ params["wo"]


# ---------------------------------------------------------------------------
# Hyena-lite
# ---------------------------------------------------------------------------

FILTER_FEATS = 16


def init_hyena(key, cfg) -> dict:
    d = cfg.d_model
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        "wv": jax.random.normal(k1, (d, d)) * std,
        "wx1": jax.random.normal(k2, (d, d)) * std,
        "wx2": jax.random.normal(k3, (d, d)) * std,
        "wo": jax.random.normal(k4, (d, d)) * std,
        # implicit filter MLP: sinusoidal pos feats -> hidden -> d channels
        "fw1": jax.random.normal(k5, (FILTER_FEATS, 32)) * FILTER_FEATS ** -0.5,
        "fw2": jax.random.normal(k6, (32, d)) * 32 ** -0.5,
        "decay": jnp.linspace(0.5, 4.0, d),
    }


def _pos_features(n: int) -> jnp.ndarray:
    t = jnp.arange(n)[:, None] / max(n, 1)
    freqs = jnp.arange(FILTER_FEATS // 2)[None, :] + 1.0
    ang = 2.0 * jnp.pi * t * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (N, F)


def _implicit_filter(params, n: int) -> jnp.ndarray:
    feats = _pos_features(n)
    h = jnp.sin(feats @ params["fw1"]) @ params["fw2"]  # (N, D)
    t = jnp.arange(n)[:, None] / max(n, 1)
    window = jnp.exp(-params["decay"][None, :] * t)  # exponential decay window
    return h * window


def _causal_fft_conv(x, h):
    """y[:, t, c] = sum_{s<=t} h[t-s, c] * x[:, s, c] via zero-padded FFT."""
    n = x.shape[1]
    m = 2 * n
    xf = jnp.fft.rfft(x, n=m, axis=1)
    hf = jnp.fft.rfft(h, n=m, axis=0)
    y = jnp.fft.irfft(xf * hf[None], n=m, axis=1)[:, :n]
    return y.astype(x.dtype)


def hyena_mixer(params, cfg, x):
    """Hyena-lite order-2 recurrence: x2 * conv(h, x1 * v)."""
    v = x @ params["wv"]
    x1 = x @ params["wx1"]
    x2 = x @ params["wx2"]
    h = _implicit_filter(params, x.shape[1])
    y = x2 * _causal_fft_conv(x1 * v, h)
    return y @ params["wo"]


MIXERS = {
    "aft": (init_aft, aft_mixer),
    "h3": (init_h3, h3_mixer),
    "hyena": (init_hyena, hyena_mixer),
}
