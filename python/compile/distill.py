"""Attention-weight distillation (paper Sec 4.2, Eq 4; Appendix A.3).

Stage 1 of finetuned/pretrained conversion: freeze every original model
weight, insert per-head feature-map MLPs after the q/k projections, and
train ONLY the MLPs so the linear attention map matches the softmax map the
frozen model computes over the same hidden states.

The graph mirrors Listing 2/3 of the paper: one forward pass of the frozen
model collects every layer's pre-attention hidden state; each layer
contributes a soft-label cross-entropy between its student (linear) and
teacher (softmax) maps; the summed loss trains all feature maps jointly
with a single AdamW.

Propagation uses the *teacher* (the model still runs softmax attention
while the maps are being distilled), exactly as in the paper's recipe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import model as model_mod
from . import train as train_mod
from .kernels import feature_maps, ref


def distill_loss(params, cfg, *inputs):
    """Summed per-layer soft-XE between student and teacher attention maps."""
    teacher_cfg = cfg.replace(attn="softmax")
    if cfg.kind == "vit":
        _, hiddens = model_mod.collect_hidden(params, teacher_cfg, None, patches=inputs[0])
    else:
        _, hiddens = model_mod.collect_hidden(params, teacher_cfg, inputs[0])

    total = 0.0
    for layer_p, h in zip(params["blocks"], hiddens):
        q, k = attn_mod.qk_heads(layer_p["mix"], cfg, h)
        true_attn = ref.softmax_attention_weights(q, k, causal=cfg.causal, scale=1.0)
        fm_params = layer_p["mix"].get("fm", {})
        qf = feature_maps.apply(cfg.attn, fm_params, q)
        kf = feature_maps.apply(cfg.attn, fm_params, k)
        pred_attn = ref.linear_attention_weights(qf, kf, causal=cfg.causal)
        total = total + ref.distill_soft_xe(pred_attn, true_attn, causal=cfg.causal)
    return total / len(hiddens)


def make_distill_step(cfg):
    """(params, m, v, step, lr, wd, *model_inputs) -> (params', m', v', step', loss).

    Only leaves under a `fm` subtree receive updates; everything else is
    frozen (gradient-masked), so the same full parameter tree flows through
    distillation and the later finetuning stage unchanged in structure.
    """

    def loss_fn(params, *inputs):
        return distill_loss(params, cfg, *inputs)

    def step_fn(params, m, v, step, lr, wd, *inputs):
        loss, grads = jax.value_and_grad(loss_fn)(params, *inputs)
        grads = train_mod.mask_grads(grads, lambda p: "/fm/" not in f"/{p}/")
        new_step = step + 1
        params, m, v = train_mod.adamw_update(params, grads, m, v, new_step, lr, wd)
        return params, m, v, new_step, loss

    return step_fn


def make_distill_eval(cfg):
    """(params, *inputs) -> (distill_loss, mean_kl) on held-out data."""

    def eval_fn(params, *inputs):
        loss = distill_loss(params, cfg, *inputs)
        kl = mean_attention_kl(params, cfg, *inputs)
        return loss, kl

    return eval_fn


def mean_attention_kl(params, cfg, *inputs):
    """Mean KL(teacher || student) across layers — Tables 4/5/14 metric."""
    teacher_cfg = cfg.replace(attn="softmax")
    if cfg.kind == "vit":
        _, hiddens = model_mod.collect_hidden(params, teacher_cfg, None, patches=inputs[0])
    else:
        _, hiddens = model_mod.collect_hidden(params, teacher_cfg, inputs[0])
    total = 0.0
    for layer_p, h in zip(params["blocks"], hiddens):
        q, k = attn_mod.qk_heads(layer_p["mix"], cfg, h)
        true_attn = ref.softmax_attention_weights(q, k, causal=cfg.causal, scale=1.0)
        fm_params = layer_p["mix"].get("fm", {})
        qf = feature_maps.apply(cfg.attn, fm_params, q)
        kf = feature_maps.apply(cfg.attn, fm_params, k)
        pred_attn = ref.linear_attention_weights(qf, kf, causal=cfg.causal)
        if cfg.causal:
            # exclude the structurally-zero upper triangle from the mean
            total = total + _causal_kl(true_attn, pred_attn)
        else:
            total = total + ref.attention_kl(true_attn, pred_attn)
    return total / len(hiddens)


def _causal_kl(true_attn, pred_attn):
    n = true_attn.shape[-2]
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    kl_terms = true_attn * (jnp.log(true_attn + ref.EPS) - jnp.log(pred_attn + ref.EPS))
    kl_terms = jnp.where(mask, kl_terms, 0.0)
    return kl_terms.sum(-1).mean()
