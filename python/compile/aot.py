"""AOT export driver: lower every compute graph to HLO text + JSON manifest.

This is the only place Python touches the pipeline — `make artifacts` runs
it once; afterwards the Rust binary is self-contained.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact `<name>` produces:
    artifacts/<name>.hlo.txt   — the lowered module
    artifacts/<name>.json      — manifest: named inputs/outputs
                                 (shape + dtype) and experiment metadata

Pytree arguments are flattened to a positional leaf list; leaf names are
jax tree paths (e.g. `params/blocks/0/mix/wq`), which is how the Rust
`ParamStore` moves parameter sets between graphs (and between model
variants during conversion: shared leaves match by name).
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import analysis, configs, decode, distill, lora, train
from . import model as model_mod
from .kernels import feature_maps
from .kernels.linear_attention import linear_attention_pallas
from .kernels.softmax_attention import softmax_attention_pallas
from .train import path_str

DTYPE_NAMES = {
    jnp.dtype("float32"): "f32",
    jnp.dtype("int32"): "i32",
    jnp.dtype("uint32"): "u32",
}


def spec_of(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def _leaf_entry(name, leaf):
    return {
        "name": name,
        "shape": [int(d) for d in leaf.shape],
        "dtype": DTYPE_NAMES[jnp.dtype(leaf.dtype)],
    }


def flatten_named(named_args):
    """[(name, pytree_of_specs)] -> (flat_specs, input_entries, unflatten)."""
    flat_all, metas = [], []
    rebuilders = []
    for name, tree in named_args:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        paths = [
            f"{name}/{path_str(p)}" if path_str(p) else name
            for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
        ]
        start = len(flat_all)
        flat_all.extend(leaves)
        metas.extend(_leaf_entry(pn, leaf) for pn, leaf in zip(paths, leaves))
        rebuilders.append((treedef, start, len(leaves)))

    def unflatten(flat):
        out = []
        for treedef, start, n in rebuilders:
            out.append(jax.tree_util.tree_unflatten(treedef, flat[start : start + n]))
        return out

    return flat_all, metas, unflatten


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Exporter:
    def __init__(self, out_dir: str, only: str | None, force: bool):
        self.out_dir = out_dir
        self.only = re.compile(only) if only else None
        self.force = force
        self.count = 0
        self.skipped = 0

    def emit(self, name, fn, named_args, out_names, meta):
        """Lower `fn(*pytrees)` (args given as [(name, spec-pytree)])."""
        if self.only and not self.only.search(name):
            return
        hlo_path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        man_path = os.path.join(self.out_dir, f"{name}.json")
        if not self.force and os.path.exists(hlo_path) and os.path.exists(man_path):
            self.skipped += 1
            return

        flat_specs, in_entries, unflatten = flatten_named(named_args)

        def wrapped(*flat):
            args = unflatten(list(flat))
            out = fn(*args)
            leaves = jax.tree_util.tree_leaves(out)
            return tuple(leaves)

        lowered = jax.jit(wrapped).lower(*flat_specs)
        text = to_hlo_text(lowered)

        # jax DCEs unused arguments out of the lowered module; the manifest
        # must describe the *compiled* signature, so filter to kept inputs.
        kept = getattr(lowered._lowering, "compile_args", {}).get("kept_var_idx")
        if kept is not None:
            in_entries = [e for i, e in enumerate(in_entries) if i in kept]

        # Output manifest entries: evaluate shapes abstractly.
        out_shapes = jax.eval_shape(wrapped, *flat_specs)
        out_leaves = jax.tree_util.tree_leaves(out_shapes)
        if len(out_names) != len(out_leaves):
            # auto-name overflow (e.g. flattened param outputs)
            out_names = list(out_names) + [
                f"out{i}" for i in range(len(out_names), len(out_leaves))
            ]
        out_entries = [_leaf_entry(n, l) for n, l in zip(out_names, out_leaves)]

        with open(hlo_path, "w") as f:
            f.write(text)
        with open(man_path, "w") as f:
            json.dump(
                {"name": name, "inputs": in_entries, "outputs": out_entries, "meta": meta},
                f,
                indent=1,
            )
        self.count += 1
        print(f"  [{self.count}] {name}: {len(in_entries)} in / {len(out_entries)} out, "
              f"{len(text)//1024} KiB hlo")


# ---------------------------------------------------------------------------
# Per-family artifact builders
# ---------------------------------------------------------------------------

def params_out_names(cfg):
    ex = jax.eval_shape(lambda: model_mod.init_params(jax.random.PRNGKey(0), cfg))
    paths = [
        f"params/{path_str(p)}"
        for p, _ in jax.tree_util.tree_flatten_with_path(ex)[0]
    ]
    return ex, paths


def cfg_meta(cfg, spec, **extra):
    m = {
        "family": cfg.name, "kind": cfg.kind, "attn": cfg.attn,
        "mixer": cfg.mixer, "vocab": cfg.vocab, "n_layers": cfg.n_layers,
        "heads": cfg.heads, "d_head": cfg.d_head, "d_model": cfg.d_model,
        "max_len": cfg.max_len, "num_classes": cfg.num_classes,
        "regression": cfg.regression, "pair_input": cfg.pair_input,
        "patch_dim": cfg.patch_dim,
        "batch_size": spec.batch_size, "seq_len": spec.seq_len,
    }
    m.update(extra)
    return m


def scalar(dtype):
    return jax.ShapeDtypeStruct((), dtype)


def export_model_variant(ex: Exporter, cfg, spec, tag, *, graphs=("init", "train", "eval", "logits"),
                         with_distill=False, seq_len=None):
    """Export the standard graph set for one (config, attn/mixer) variant."""
    seq = seq_len or spec.seq_len
    params_spec, p_names = params_out_names(cfg)
    batch = train.batch_specs(cfg, spec.batch_size, seq)
    batch_named = [(n, s) for n, s in batch]
    opt_named = [
        ("m", params_spec), ("v", params_spec),
        ("step", scalar(jnp.int32)), ("lr", scalar(jnp.float32)),
        ("wd", scalar(jnp.float32)),
    ]
    meta = cfg_meta(cfg, spec, seq_len=seq)

    if "init" in graphs:
        ex.emit(
            f"{tag}_init",
            lambda seed: train.make_init(cfg)(seed),
            [("seed", scalar(jnp.uint32))],
            p_names,
            {**meta, "graph": "init"},
        )
    if "train" in graphs:
        step_fn = train.make_train_step(cfg)
        ex.emit(
            f"{tag}_train_step",
            step_fn,
            [("params", params_spec)] + opt_named + batch_named,
            p_names + [n.replace("params/", "m/") for n in p_names]
            + [n.replace("params/", "v/") for n in p_names]
            + ["step", "loss"],
            {**meta, "graph": "train_step"},
        )
    if "eval" in graphs:
        ex.emit(
            f"{tag}_eval",
            train.make_eval(cfg),
            [("params", params_spec)] + batch_named,
            ["loss", "metric"],
            {**meta, "graph": "eval"},
        )
    if "logits" in graphs:
        inputs = batch[: 2 if cfg.pair_input else 1]
        ex.emit(
            f"{tag}_logits",
            train.make_logits(cfg),
            [("params", params_spec)] + [(n, s) for n, s in inputs],
            ["logits"],
            {**meta, "graph": "logits"},
        )
    if "stats" in graphs:
        inputs = batch[: 2 if cfg.pair_input else 1]
        ex.emit(
            f"{tag}_attn_stats",
            analysis.make_attn_stats(cfg),
            [("params", params_spec)] + [(n, s) for n, s in inputs],
            ["teacher_entropy", "student_entropy", "kl"],
            {**meta, "graph": "attn_stats"},
        )
    if "mono" in graphs:
        inputs = batch[:1]
        ex.emit(
            f"{tag}_mono_probe",
            analysis.make_mono_probe(cfg),
            [("params", params_spec)] + [(n, s) for n, s in inputs],
            ["dots", "teacher_w", "student_w"],
            {**meta, "graph": "mono_probe"},
        )
    if "dump" in graphs:
        inputs = batch[:1]
        ex.emit(
            f"{tag}_attn_dump",
            analysis.make_attn_dump(cfg),
            [("params", params_spec)] + [(n, s) for n, s in inputs],
            ["teacher_map", "student_map"],
            {**meta, "graph": "attn_dump"},
        )
    if with_distill:
        dstep = distill.make_distill_step(cfg)
        inputs = batch[: 2 if cfg.pair_input else 1]
        ex.emit(
            f"{tag}_distill_step",
            dstep,
            [("params", params_spec)] + opt_named + [(n, s) for n, s in inputs],
            p_names + [n.replace("params/", "m/") for n in p_names]
            + [n.replace("params/", "v/") for n in p_names]
            + ["step", "loss"],
            {**meta, "graph": "distill_step"},
        )
        ex.emit(
            f"{tag}_distill_eval",
            distill.make_distill_eval(cfg),
            [("params", params_spec)] + [(n, s) for n, s in inputs],
            ["distill_loss", "kl"],
            {**meta, "graph": "distill_eval"},
        )


def export_decode(ex: Exporter, cfg, spec, tag, batch_size=None):
    """Recurrent decode_step + prefill for a linear-attention decoder."""
    b = batch_size or spec.batch_size
    params_spec, _ = params_out_names(cfg)
    fn, dp = decode.make_decode_step(cfg)
    L, H, DV = cfg.n_layers, cfg.heads, cfg.d_head
    named = [
        ("params", params_spec),
        ("token", jax.ShapeDtypeStruct((b,), jnp.int32)),
        ("pos", jax.ShapeDtypeStruct((b,), jnp.int32)),
        ("s", jax.ShapeDtypeStruct((L, b, H, dp, DV), jnp.float32)),
        ("z", jax.ShapeDtypeStruct((L, b, H, dp), jnp.float32)),
    ]
    meta = cfg_meta(cfg, spec, graph="decode_step", feature_dim=dp, decode_batch=b)
    ex.emit(f"{tag}_decode_step", fn, named, ["logits", "s", "z"], meta)


def export_decode_softmax(ex: Exporter, cfg, spec, tag, batch_size=None, max_len=None):
    b = batch_size or spec.batch_size
    n = max_len or cfg.max_len
    params_spec, _ = params_out_names(cfg)
    fn = decode.make_decode_step_softmax(cfg, n)
    L, H, DH = cfg.n_layers, cfg.heads, cfg.d_head
    named = [
        ("params", params_spec),
        ("token", jax.ShapeDtypeStruct((b,), jnp.int32)),
        ("pos", jax.ShapeDtypeStruct((b,), jnp.int32)),
        ("k_cache", jax.ShapeDtypeStruct((L, b, H, n, DH), jnp.float32)),
        ("v_cache", jax.ShapeDtypeStruct((L, b, H, n, DH), jnp.float32)),
    ]
    meta = cfg_meta(cfg, spec, graph="decode_step_softmax", cache_len=n, decode_batch=b)
    ex.emit(f"{tag}_decode_step_softmax", fn, named, ["logits", "k_cache", "v_cache"], meta)


def export_lora(ex: Exporter, cfg, spec, tag, rank=8, alpha=16.0):
    params_spec, _ = params_out_names(cfg)
    ad_spec = jax.eval_shape(lambda: lora.init_lora(jax.random.PRNGKey(0), cfg, rank))
    ad_leaves = [
        f"lora/{path_str(p)}"
        for p, _ in jax.tree_util.tree_flatten_with_path(ad_spec)[0]
    ]
    batch = train.batch_specs(cfg, spec.batch_size, spec.seq_len)
    meta = cfg_meta(cfg, spec, lora_rank=rank, lora_alpha=alpha)

    ex.emit(
        f"{tag}_lora_init",
        lambda seed: lora.init_lora(jax.random.PRNGKey(seed), cfg, rank),
        [("seed", scalar(jnp.uint32))],
        ad_leaves,
        {**meta, "graph": "lora_init"},
    )
    step_fn = lora.make_lora_train_step(cfg, alpha, rank)
    ex.emit(
        f"{tag}_lora_train_step",
        step_fn,
        [
            ("base", params_spec), ("lora", ad_spec),
            ("m", ad_spec), ("v", ad_spec),
            ("step", scalar(jnp.int32)), ("lr", scalar(jnp.float32)),
            ("wd", scalar(jnp.float32)),
        ] + [(n, s) for n, s in batch],
        ad_leaves + [n.replace("lora/", "m/") for n in ad_leaves]
        + [n.replace("lora/", "v/") for n in ad_leaves] + ["step", "loss"],
        {**meta, "graph": "lora_train_step"},
    )
    ex.emit(
        f"{tag}_lora_eval",
        lora.make_lora_eval(cfg, alpha, rank),
        [("base", params_spec), ("lora", ad_spec)] + [(n, s) for n, s in batch],
        ["loss", "metric"],
        {**meta, "graph": "lora_eval"},
    )
    ex.emit(
        f"{tag}_lora_logits",
        lora.make_lora_logits(cfg, alpha, rank),
        [("base", params_spec), ("lora", ad_spec)] + [(n, s) for n, s in batch[:1]],
        ["logits"],
        {**meta, "graph": "lora_logits"},
    )


# ---------------------------------------------------------------------------
# Standalone kernel / scaling artifacts (Fig 6 + integration smoke tests)
# ---------------------------------------------------------------------------

def export_kernels(ex: Exporter):
    b, h, n, d = 1, 2, 128, 16
    qkv = [
        ("q", jax.ShapeDtypeStruct((b, h, n, d), jnp.float32)),
        ("k", jax.ShapeDtypeStruct((b, h, n, d), jnp.float32)),
        ("v", jax.ShapeDtypeStruct((b, h, n, d), jnp.float32)),
    ]
    ex.emit(
        "kernel_linear_attention",
        lambda q, k, v: linear_attention_pallas(jnp.exp(q), jnp.exp(k), v, 32),
        qkv,
        ["out"],
        {"graph": "kernel", "kernel": "linear_attention", "b": b, "h": h, "n": n, "d": d},
    )
    ex.emit(
        "kernel_softmax_attention",
        lambda q, k, v: softmax_attention_pallas(q, k, v, 32),
        qkv,
        ["out"],
        {"graph": "kernel", "kernel": "softmax_attention", "b": b, "h": h, "n": n, "d": d},
    )


FIG6_HEADS = 4
FIG6_DHEAD = 64
FIG6_SOFTMAX_LENS = [256, 512, 1024, 2048, 4096]
FIG6_LINEAR_LENS = [256, 512, 1024, 2048, 4096, 8192, 16384]


def export_fig6(ex: Exporter):
    """Single attention-layer forward at many sequence lengths (Fig 6)."""
    h, d = FIG6_HEADS, FIG6_DHEAD

    for n in FIG6_SOFTMAX_LENS:
        spec = jax.ShapeDtypeStruct((1, h, n, d), jnp.float32)
        ex.emit(
            f"fig6_softmax_n{n}",
            lambda q, k, v: softmax_attention_pallas(q, k, v, 64),
            [("q", spec), ("k", spec), ("v", spec)],
            ["out"],
            {"graph": "fig6", "attn": "softmax", "n": n, "heads": h, "d_head": d},
        )
    for n in FIG6_LINEAR_LENS:
        spec = jax.ShapeDtypeStruct((1, h, n, d), jnp.float32)

        def hh(q, k, v):
            qf = jnp.concatenate([jnp.exp(q), jnp.exp(-q)], -1)
            kf = jnp.concatenate([jnp.exp(k), jnp.exp(-k)], -1)
            return linear_attention_pallas(qf, kf, v, 64)

        ex.emit(
            f"fig6_hedgehog_n{n}",
            hh,
            [("q", spec), ("k", spec), ("v", spec)],
            ["out"],
            {"graph": "fig6", "attn": "hedgehog", "n": n, "heads": h, "d_head": d},
        )
    for n in FIG6_SOFTMAX_LENS[:4]:  # taylor d'=d^2 is heavy; cap the sweep
        spec = jax.ShapeDtypeStruct((1, h, n, d), jnp.float32)

        def taylor(q, k, v):
            from .kernels import ref

            qf = ref.feature_taylor(q * d ** -0.25)
            kf = ref.feature_taylor(k * d ** -0.25)
            return linear_attention_pallas(qf, kf, v, 64)

        ex.emit(
            f"fig6_taylor_n{n}",
            taylor,
            [("q", spec), ("k", spec), ("v", spec)],
            ["out"],
            {"graph": "fig6", "attn": "taylor", "n": n, "heads": h, "d_head": d},
        )


# ---------------------------------------------------------------------------
# The full experiment grid
# ---------------------------------------------------------------------------

def export_all(ex: Exporter):
    # --- kernels + fig6 scaling -------------------------------------------
    export_kernels(ex)
    export_fig6(ex)

    # --- AR: train-from-scratch, all maps (Figs 2/4, Tables 2/3) ----------
    cfg0, spec = configs.family("ar")
    for attn in ["softmax"] + configs.PRIOR_MAPS + ["taylor", "hedgehog"]:
        cfg = cfg0.replace(attn=attn)
        export_model_variant(
            ex, cfg, spec, f"ar_{attn}",
            graphs=("init", "train", "eval", "stats"),
        )

    # --- GLUE-like encoders (Tables 1/8/15, Figs 3/5/7, Tables 4/5) -------
    # Head variants: 2-class (most tasks), 3-class (mnli), regression (stsb).
    glue0, gspec = configs.family("glue")
    heads = {
        "glue2": glue0,
        "glue3": glue0.replace(num_classes=3),
        "gluer": glue0.replace(num_classes=1, regression=True),
    }
    for hname, base in heads.items():
        # softmax teacher
        export_model_variant(ex, base.replace(attn="softmax"), gspec, f"{hname}_softmax",
                             graphs=("init", "train", "eval", "logits", "stats", "mono", "dump"))
        # converted students
        maps = (
            configs.PRIOR_MAPS + ["taylor", "hedgehog", "t2r"]
            if hname == "glue2"
            else ["hedgehog", "t2r"]
        )
        for attn in maps:
            cfg = base.replace(attn=attn)
            trainable = attn in ("hedgehog", "t2r", "hedgehog_sm")
            export_model_variant(
                ex, cfg, gspec, f"{hname}_{attn}",
                graphs=("init", "train", "eval", "logits", "stats", "mono", "dump")
                if hname == "glue2"
                else ("init", "train", "eval", "logits"),
                with_distill=trainable,
            )
    # Context-length generalization (Table 5): hedgehog distill_eval at longer N.
    for n in [64, 128, 256]:
        cfg = glue0.replace(attn="hedgehog", max_len=n)
        sp = configs.TrainSpec(batch_size=4, seq_len=n)
        params_spec, _ = params_out_names(cfg)
        ex.emit(
            f"glue2_hedgehog_distill_eval_n{n}",
            distill.make_distill_eval(cfg),
            [("params", params_spec),
             ("tokens", jax.ShapeDtypeStruct((4, n), jnp.int32))],
            ["distill_loss", "kl"],
            cfg_meta(cfg, sp, graph="distill_eval", ctx_len=n),
        )

    # --- LM: from-scratch (Table 7) + pretrained conversion (Table 10) ----
    lm0, lspec = configs.family("lm")
    for attn in ["softmax", "elu", "performer", "hedgehog"]:
        export_model_variant(ex, lm0.replace(attn=attn), lspec, f"lm_{attn}")
    for mixer in ["aft", "h3", "hyena"]:
        export_model_variant(ex, lm0.replace(mixer=mixer), lspec, f"lm_{mixer}")
    for attn in ["hedgehog", "t2r"]:
        export_model_variant(
            ex, lm0.replace(attn=attn), lspec, f"lmconv_{attn}",
            graphs=(), with_distill=True,
        )
    export_decode(ex, lm0.replace(attn="hedgehog"), lspec, "lm_hedgehog", batch_size=4)
    export_decode_softmax(ex, lm0, lspec, "lm_softmax", batch_size=4)

    # --- LRA-like (Table 6/13) --------------------------------------------
    for fam in ["lra_listops", "lra_text", "lra_retrieval", "lra_image", "lra_pathfinder"]:
        c0, sp = configs.family(fam)
        for attn in ["softmax", "elu", "performer", "cosformer", "hedgehog"]:
            export_model_variant(ex, c0.replace(attn=attn), sp, f"{fam}_{attn}")

    # --- ViT (Table 9) ------------------------------------------------------
    vit0, vspec = configs.family("vit")
    export_model_variant(ex, vit0.replace(attn="softmax"), vspec, "vit_softmax")
    for attn in ["hedgehog", "t2r"]:
        export_model_variant(
            ex, vit0.replace(attn=attn), vspec, f"vit_{attn}", with_distill=True
        )

    # --- Summarization + LoRA (Table 11) ------------------------------------
    sum0, sspec = configs.family("sum")
    export_model_variant(
        ex, sum0.replace(attn="softmax"), sspec, "sum_softmax",
        graphs=("init", "train", "eval", "logits"),
    )
    export_lora(ex, sum0.replace(attn="softmax"), sspec, "sum_softmax")
    for attn in ["hedgehog", "t2r"]:
        cfg = sum0.replace(attn=attn)
        export_model_variant(
            ex, cfg, sspec, f"sum_{attn}",
            graphs=("init", "logits"), with_distill=True,
        )
        export_lora(ex, cfg, sspec, f"sum_{attn}")

    # --- End-to-end example drivers ------------------------------------------
    for fam in ["e2e_small", "e2e_medium"]:
        c0, sp = configs.family(fam)
        for attn in ["softmax", "hedgehog"]:
            export_model_variant(ex, c0.replace(attn=attn), sp, f"{fam}_{attn}")
        export_decode(ex, c0.replace(attn="hedgehog"), sp, f"{fam}_hedgehog", batch_size=2)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    ap.add_argument("--force", action="store_true", help="rebuild even if present")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    ex = Exporter(args.out, args.only, args.force)
    export_all(ex)
    print(f"wrote {ex.count} artifacts ({ex.skipped} already present) -> {args.out}")


if __name__ == "__main__":
    main()
