"""Multi-head attention with pluggable similarity: softmax or any feature map.

This is the L2 glue between the model blocks and the L1 kernels:

  * `attn="softmax"`  -> quadratic softmax attention (jnp reference math for
    training graphs; the Pallas flash kernel is exported separately for the
    forward/serving artifacts and Fig 6).
  * any feature-map name from kernels.feature_maps -> linear attention via
    the chunked Pallas kernel (causal) or the closed-form full-sequence
    state (bidirectional encoders).

Per the paper (Sec 4.2 / A.2) the Hedgehog MLP is inserted after the q/k
projections, one map per head per layer, and the *same* map is applied to
queries and keys. Queries and keys are pre-scaled by d_head**-0.25 each so
every similarity sees the softmax temperature of Eq. 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import feature_maps, ref
from .kernels.linear_attention import linear_attention_pallas, linear_attention_scan

ATTN_CHUNK = 64  # sequence chunk for the Pallas kernel; seq lens are multiples


def init_attention(key, cfg, layer_idx: int) -> dict:
    """Parameters for one attention layer (projections + optional feature map)."""
    d, h, dh = cfg.d_model, cfg.heads, cfg.d_head
    inner = h * dh
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    std = d ** -0.5
    params = {
        "wq": jax.random.normal(k1, (d, inner)) * std,
        "wk": jax.random.normal(k2, (d, inner)) * std,
        "wv": jax.random.normal(k3, (d, inner)) * std,
        "wo": jax.random.normal(k4, (inner, d)) * std,
    }
    if cfg.attn != "softmax" and feature_maps.get(cfg.attn).trainable:
        params["fm"] = feature_maps.init_params(cfg.attn, k5, h, dh)
    return params


def split_heads(x, heads):
    b, n, hd = x.shape
    return x.reshape(b, n, heads, hd // heads).transpose(0, 2, 1, 3)


def merge_heads(x):
    b, h, n, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def _features(cfg, params, q, k):
    """Apply the configured feature map to pre-scaled q and k."""
    fm_params = params.get("fm", {})
    if cfg.attn == "performer":
        # Fixed (non-trainable) FAVOR+ projection, deterministic per config:
        # generated from a constant key so it constant-folds into the HLO.
        proj = jax.random.normal(jax.random.PRNGKey(1234 + cfg.d_head), (cfg.d_head, cfg.d_head))
        return ref.feature_performer(q, proj), ref.feature_performer(k, proj)
    qf = feature_maps.apply(cfg.attn, fm_params, q)
    kf = feature_maps.apply(cfg.attn, fm_params, k)
    return qf, kf


def attention(params: dict, cfg, x: jnp.ndarray, *, use_pallas: bool = True):
    """Full multi-head attention over (B, N, D) hidden states."""
    h, dh = cfg.heads, cfg.d_head
    q = split_heads(x @ params["wq"], h)
    k = split_heads(x @ params["wk"], h)
    v = split_heads(x @ params["wv"], h)

    scale = dh ** -0.25
    q = q * scale
    k = k * scale

    if cfg.attn == "softmax":
        out = ref.softmax_attention(q, k, v, causal=cfg.causal, scale=1.0)
    else:
        qf, kf = _features(cfg, params, q, k)
        if cfg.causal:
            n = x.shape[1]
            if use_pallas and n % ATTN_CHUNK == 0:
                out = linear_attention_pallas(qf, kf, v, ATTN_CHUNK)
            else:
                chunk = min(ATTN_CHUNK, n)
                chunk = n // max(1, n // chunk)  # largest divisor <= chunk
                while n % chunk != 0:
                    chunk -= 1
                out = linear_attention_scan(qf, kf, v, chunk)
        else:
            out = ref.linear_attention_noncausal(qf, kf, v)

    return merge_heads(out) @ params["wo"]


def attention_weights(params: dict, cfg, x: jnp.ndarray, attn: str | None = None):
    """Materialized (B, H, N, N) attention map for analysis/distillation.

    `attn` overrides the config's similarity (e.g. compute the softmax
    teacher map on a model configured with a linear student).
    """
    name = cfg.attn if attn is None else attn
    h, dh = cfg.heads, cfg.d_head
    q = split_heads(x @ params["wq"], h) * dh ** -0.25
    k = split_heads(x @ params["wk"], h) * dh ** -0.25
    if name == "softmax":
        return ref.softmax_attention_weights(q, k, causal=cfg.causal, scale=1.0)
    sub_cfg_attn = cfg.attn
    if name == "performer":
        proj = jax.random.normal(jax.random.PRNGKey(1234 + cfg.d_head), (dh, dh))
        qf, kf = ref.feature_performer(q, proj), ref.feature_performer(k, proj)
    else:
        fm_params = params.get("fm", {}) if name == sub_cfg_attn else {}
        if feature_maps.get(name).trainable and name != sub_cfg_attn:
            # untrained comparison map: identity init
            fm_params = feature_maps.init_params(name, jax.random.PRNGKey(0), h, dh)
        qf = feature_maps.apply(name, fm_params, q)
        kf = feature_maps.apply(name, fm_params, k)
    return ref.linear_attention_weights(qf, kf, causal=cfg.causal)


def qk_heads(params: dict, cfg, x: jnp.ndarray):
    """Pre-scaled per-head q, k — the raw material for distillation (Eq. 4)."""
    h, dh = cfg.heads, cfg.d_head
    q = split_heads(x @ params["wq"], h) * dh ** -0.25
    k = split_heads(x @ params["wk"], h) * dh ** -0.25
    return q, k
