"""Single-token decode steps — the serving engine's hot path.

Two families, both exported per decoder config:

  * `decode_step` (linear attention): carries the O(1) recurrent state
      S (L, B, H, Dp, Dv) and z (L, B, H, Dp)
    per layer. One call = embed token -> L blocks of (feature map, state
    update, readout, MLP) -> next-token logits. Cost is independent of how
    many tokens came before — the paper's Fig 6 inference claim.

  * `decode_step_softmax` (quadratic baseline): carries a KV cache
    (L, B, H, MAXLEN, Dh) pair and attends over the valid prefix with a
    position mask. Cost grows linearly per token (quadratic per sequence).

The Rust `serve::Engine` threads these states through PJRT buffers across
calls; batch slots map to the B axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as model_mod
from .kernels import feature_maps
from .kernels.linear_attention import EPS, linear_attention_decode_step


def _block_token(layer_p, cfg, x, attn_out):
    """Residual + MLP half of a block for a single token (B, D)."""
    x = x + attn_out
    h = model_mod.layer_norm(layer_p["ln2"], x)
    return x + model_mod.mlp(layer_p["mlp"], h)


def _qkv_token(layer_p, cfg, h):
    """Per-head q, k, v for a single token; h is (B, D)."""
    hh, dh = cfg.heads, cfg.d_head
    q = (h @ layer_p["mix"]["wq"]).reshape(-1, hh, dh)
    k = (h @ layer_p["mix"]["wk"]).reshape(-1, hh, dh)
    v = (h @ layer_p["mix"]["wv"]).reshape(-1, hh, dh)
    scale = dh ** -0.25
    return q * scale, k * scale, v


def make_decode_step(cfg):
    """Linear-attention decode: (params, token, pos, S, Z) -> (logits, S', Z').

    token (B,) i32; pos (B,) i32; S (L,B,H,Dp,Dv); Z (L,B,H,Dp).
    """
    fm = feature_maps.get(cfg.attn)
    dp = fm.feature_dim(cfg.d_head)

    def fn(params, token, pos, s_all, z_all):
        x = params["emb"][token] + params["pos"][pos]  # (B, D)
        new_s, new_z = [], []
        for li, layer_p in enumerate(params["blocks"]):
            h = model_mod.layer_norm(layer_p["ln1"], x)
            q, k, v = _qkv_token(layer_p, cfg, h)
            fm_params = layer_p["mix"].get("fm", {})
            # feature maps expect (B,H,N,D); add/remove a singleton N axis
            qf = feature_maps.apply(cfg.attn, fm_params, q[:, :, None, :])[:, :, 0]
            kf = feature_maps.apply(cfg.attn, fm_params, k[:, :, None, :])[:, :, 0]
            s, z, y = linear_attention_decode_step(s_all[li], z_all[li], qf, kf, v)
            new_s.append(s)
            new_z.append(z)
            attn_out = y.reshape(y.shape[0], -1) @ layer_p["mix"]["wo"]
            x = _block_token(layer_p, cfg, x, attn_out)
        x = model_mod.layer_norm(params["ln_f"], x)
        logits = x @ params["head"]
        return logits, jnp.stack(new_s), jnp.stack(new_z)

    return fn, dp


def make_decode_step_softmax(cfg, max_len: int | None = None):
    """KV-cache decode: (params, token, pos, Kc, Vc) -> (logits, Kc', Vc').

    Kc, Vc are (L, B, H, MAXLEN, Dh); `pos` is the number of tokens already
    in the cache (the new token is written at index pos).
    """
    n = max_len or cfg.max_len

    def fn(params, token, pos, k_cache, v_cache):
        x = params["emb"][token] + params["pos"][pos]
        new_k, new_v = [], []
        idx = jnp.arange(n)
        for li, layer_p in enumerate(params["blocks"]):
            h = model_mod.layer_norm(layer_p["ln1"], x)
            q, k, v = _qkv_token(layer_p, cfg, h)
            kc = _write_cache(k_cache[li], k, pos)
            vc = _write_cache(v_cache[li], v, pos)
            new_k.append(kc)
            new_v.append(vc)
            scores = jnp.einsum("bhd,bhnd->bhn", q, kc)
            valid = idx[None, :] <= pos[:, None]          # (B, N)
            scores = jnp.where(valid[:, None, :], scores, -1e30)
            w = jax.nn.softmax(scores, axis=-1)
            y = jnp.einsum("bhn,bhnd->bhd", w, vc)
            attn_out = y.reshape(y.shape[0], -1) @ layer_p["mix"]["wo"]
            x = _block_token(layer_p, cfg, x, attn_out)
        x = model_mod.layer_norm(params["ln_f"], x)
        return x @ params["head"], jnp.stack(new_k), jnp.stack(new_v)

    return fn


def _write_cache(cache, val, pos):
    """cache (B,H,N,Dh), val (B,H,Dh), pos (B,) -> cache with val at [b,:,pos[b]]."""
    n = cache.shape[2]
    onehot = jax.nn.one_hot(pos, n, dtype=cache.dtype)  # (B, N)
    return cache * (1.0 - onehot[:, None, :, None]) + (
        val[:, :, None, :] * onehot[:, None, :, None]
    )


def make_prefill(cfg):
    """(params, tokens) -> (logits_last, S, Z): process a whole prompt with
    the chunked kernel, returning the recurrent state for decode."""
    fm = feature_maps.get(cfg.attn)
    dp = fm.feature_dim(cfg.d_head)

    def fn(params, tokens):
        b, n = tokens.shape
        x = model_mod.embed_tokens(params, cfg, tokens)
        s_out, z_out = [], []
        for layer_p in params["blocks"]:
            h = model_mod.layer_norm(layer_p["ln1"], x)
            hh, dh = cfg.heads, cfg.d_head
            q = attn_mod_split(h @ layer_p["mix"]["wq"], hh) * dh ** -0.25
            k = attn_mod_split(h @ layer_p["mix"]["wk"], hh) * dh ** -0.25
            v = attn_mod_split(h @ layer_p["mix"]["wv"], hh)
            fm_params = layer_p["mix"].get("fm", {})
            qf = feature_maps.apply(cfg.attn, fm_params, q)
            kf = feature_maps.apply(cfg.attn, fm_params, k)
            from .kernels.linear_attention import linear_attention_scan

            y = linear_attention_scan(qf, kf, v, min(64, n))
            s_out.append(jnp.einsum("bhnp,bhnd->bhpd", kf, v))
            z_out.append(kf.sum(axis=2))
            attn_out = y.transpose(0, 2, 1, 3).reshape(b, n, -1) @ layer_p["mix"]["wo"]
            x = x + attn_out
            x = x + model_mod.mlp(layer_p["mlp"], model_mod.layer_norm(layer_p["ln2"], x))
        x = model_mod.layer_norm(params["ln_f"], x)
        logits = x[:, -1] @ params["head"]
        return logits, jnp.stack(s_out), jnp.stack(z_out)

    return fn, dp


def attn_mod_split(x, heads):
    b, n, hd = x.shape
    return x.reshape(b, n, heads, hd // heads).transpose(0, 2, 1, 3)
