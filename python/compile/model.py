"""L2 model zoo: GPT-lite decoder, BERT-lite encoder, ViT-lite — all with
pluggable attention (softmax or any linear feature map) and pluggable
sequence mixers (AFT / H3 / Hyena baselines).

Everything is a pure function over an explicit parameter pytree, so each
graph AOT-lowers to a self-contained HLO module the Rust runtime executes.
Pre-LN residual blocks; learned absolute positional embeddings; untied LM
head; no dropout (training runs are deterministic, which keeps the Rust
driver and EXPERIMENTS.md reproducible bit-for-bit).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import baselines


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters for one model family instance (see configs.py)."""

    name: str
    kind: str  # "decoder" | "encoder" | "vit"
    vocab: int
    n_layers: int
    heads: int
    d_head: int
    d_model: int
    max_len: int
    attn: str = "softmax"          # "softmax" or a feature-map name
    mixer: str = "attention"       # "attention" | "aft" | "h3" | "hyena"
    mlp_mult: int = 4
    num_classes: int | None = None  # encoder/vit classification head
    regression: bool = False        # encoder scalar-regression head (STS-B-like)
    patch_dim: int | None = None    # vit: flattened patch size
    pair_input: bool = False        # encoder consumes two sequences (retrieval)

    @property
    def causal(self) -> bool:
        return self.kind == "decoder"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_ln(d):
    return {"g": jnp.ones((d,)), "b": jnp.zeros((d,))}


def _init_mlp(key, d, mult):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d, mult * d)) * d ** -0.5,
        "b1": jnp.zeros((mult * d,)),
        "w2": jax.random.normal(k2, (mult * d, d)) * (mult * d) ** -0.5,
        "b2": jnp.zeros((d,)),
    }


def _init_block(key, cfg):
    k1, k2 = jax.random.split(key)
    if cfg.mixer == "attention":
        mix = attn_mod.init_attention(k1, cfg, 0)
    else:
        mix = baselines.MIXERS[cfg.mixer][0](k1, cfg)
    return {
        "ln1": _init_ln(cfg.d_model),
        "mix": mix,
        "ln2": _init_ln(cfg.d_model),
        "mlp": _init_mlp(k2, cfg.d_model, cfg.mlp_mult),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    """Initialize the full parameter pytree for a config."""
    keys = jax.random.split(key, cfg.n_layers + 4)
    d = cfg.d_model
    params: dict = {
        "pos": jax.random.normal(keys[0], (cfg.max_len, d)) * 0.02,
        "ln_f": _init_ln(d),
        "blocks": [_init_block(keys[2 + i], cfg) for i in range(cfg.n_layers)],
    }
    if cfg.kind == "vit":
        assert cfg.patch_dim is not None
        params["patch_proj"] = jax.random.normal(keys[1], (cfg.patch_dim, d)) * cfg.patch_dim ** -0.5
        params["cls"] = jax.random.normal(keys[-1], (1, 1, d)) * 0.02
    else:
        params["emb"] = jax.random.normal(keys[1], (cfg.vocab, d)) * 0.02
    if cfg.kind == "decoder":
        params["head"] = jax.random.normal(keys[-1], (d, cfg.vocab)) * d ** -0.5
    else:
        n_out = 1 if cfg.regression else (cfg.num_classes or 2)
        in_dim = 2 * d if cfg.pair_input else d
        params["head"] = jax.random.normal(keys[-1], (in_dim, n_out)) * in_dim ** -0.5
        params["head_b"] = jnp.zeros((n_out,))
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def layer_norm(p, x, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def mlp(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def block(p, cfg, x, collect=None):
    h = layer_norm(p["ln1"], x)
    if collect is not None:
        collect.append(h)  # pre-attention hidden state (distillation hook)
    if cfg.mixer == "attention":
        x = x + attn_mod.attention(p["mix"], cfg, h)
    else:
        x = x + baselines.MIXERS[cfg.mixer][1](p["mix"], cfg, h)
    x = x + mlp(p["mlp"], layer_norm(p["ln2"], x))
    return x


def embed_tokens(params, cfg, tokens):
    n = tokens.shape[1]
    x = params["emb"][tokens] + params["pos"][:n][None]
    return x


def backbone(params, cfg, x, collect=None):
    for p in params["blocks"]:
        x = block(p, cfg, x, collect)
    return layer_norm(params["ln_f"], x)


def decoder_logits(params, cfg, tokens):
    """(B, N) int32 tokens -> (B, N, vocab) next-token logits."""
    x = backbone(params, cfg, embed_tokens(params, cfg, tokens))
    return x @ params["head"]


def encoder_pooled(params, cfg, tokens):
    """Mean-pooled encoder representation (B, D)."""
    x = backbone(params, cfg, embed_tokens(params, cfg, tokens))
    return x.mean(axis=1)


def encoder_logits(params, cfg, tokens, tokens2=None):
    """Classification (B, C) / regression (B, 1) head over pooled states."""
    pooled = encoder_pooled(params, cfg, tokens)
    if cfg.pair_input:
        pooled2 = encoder_pooled(params, cfg, tokens2)
        pooled = jnp.concatenate([pooled, pooled2], axis=-1)
    return pooled @ params["head"] + params["head_b"]


def vit_logits(params, cfg, patches):
    """(B, P, patch_dim) f32 patches -> (B, C) class logits."""
    b = patches.shape[0]
    x = patches @ params["patch_proj"]
    cls = jnp.broadcast_to(params["cls"], (b, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1)
    x = x + params["pos"][: x.shape[1]][None]
    x = backbone(params, cfg, x)
    return x[:, 0] @ params["head"] + params["head_b"]


def collect_hidden(params, cfg, tokens, patches=None):
    """Run the backbone collecting per-layer pre-attention hidden states.

    Returns (final_x, [h_1 .. h_L]) — the inputs each attention layer saw.
    Used by the distillation and analysis graphs (teacher and student q/k
    are both computed from these)."""
    if cfg.kind == "vit":
        b = patches.shape[0]
        x = patches @ params["patch_proj"]
        cls = jnp.broadcast_to(params["cls"], (b, 1, cfg.d_model))
        x = jnp.concatenate([cls, x], axis=1) + params["pos"][: patches.shape[1] + 1][None]
    else:
        x = embed_tokens(params, cfg, tokens)
    collect: list = []
    x = backbone(params, cfg, x, collect=collect)
    return x, collect


def forward(params, cfg, *inputs):
    """Dispatch to the config's forward: logits of the right shape."""
    if cfg.kind == "decoder":
        return decoder_logits(params, cfg, inputs[0])
    if cfg.kind == "encoder":
        if cfg.pair_input:
            return encoder_logits(params, cfg, inputs[0], inputs[1])
        return encoder_logits(params, cfg, inputs[0])
    if cfg.kind == "vit":
        return vit_logits(params, cfg, inputs[0])
    raise ValueError(cfg.kind)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
