"""L2 correctness: model graphs, optimizer, distillation, decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import configs, decode, distill, lora, train
from compile import model as model_mod
from compile.model import ModelConfig


def tiny_decoder(attn="softmax", **kw):
    base = dict(
        name="t", kind="decoder", vocab=32, n_layers=2, heads=2,
        d_head=8, d_model=32, max_len=32, attn=attn,
    )
    base.update(kw)
    return ModelConfig(**base)


def tiny_encoder(attn="softmax", **kw):
    base = dict(
        name="t", kind="encoder", vocab=32, n_layers=2, heads=2,
        d_head=8, d_model=32, max_len=32, num_classes=3, attn=attn,
    )
    base.update(kw)
    return ModelConfig(**base)


class TestForwardShapes:
    @pytest.mark.parametrize("attn", ["softmax", "elu", "hedgehog", "taylor", "cosformer"])
    def test_decoder_logits_shape(self, attn):
        cfg = tiny_decoder(attn)
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.zeros((2, 32), jnp.int32)
        out = model_mod.decoder_logits(params, cfg, toks)
        assert out.shape == (2, 32, 32)
        assert np.isfinite(np.asarray(out)).all()

    @pytest.mark.parametrize("attn", ["softmax", "hedgehog", "performer"])
    def test_encoder_logits_shape(self, attn):
        cfg = tiny_encoder(attn)
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.zeros((2, 32), jnp.int32)
        out = model_mod.encoder_logits(params, cfg, toks)
        assert out.shape == (2, 3)

    def test_pair_encoder(self):
        cfg = tiny_encoder(pair_input=True, num_classes=2)
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        t = jnp.zeros((2, 32), jnp.int32)
        out = model_mod.encoder_logits(params, cfg, t, t)
        assert out.shape == (2, 2)

    def test_vit(self):
        cfg = ModelConfig(
            name="v", kind="vit", vocab=0, n_layers=1, heads=2, d_head=8,
            d_model=32, max_len=17, num_classes=10, patch_dim=16,
        )
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        patches = jnp.zeros((2, 16, 16))
        assert model_mod.vit_logits(params, cfg, patches).shape == (2, 10)

    @pytest.mark.parametrize("mixer", ["aft", "h3", "hyena"])
    def test_baseline_mixers(self, mixer):
        cfg = tiny_decoder(mixer=mixer)
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        out = model_mod.decoder_logits(params, cfg, jnp.zeros((2, 32), jnp.int32))
        assert out.shape == (2, 32, 32)
        assert np.isfinite(np.asarray(out)).all()

    @pytest.mark.parametrize("mixer", ["aft", "h3", "hyena"])
    def test_baseline_mixers_causal(self, mixer):
        """Changing future tokens must not change past logits."""
        cfg = tiny_decoder(mixer=mixer)
        params = model_mod.init_params(jax.random.PRNGKey(1), cfg)
        t1 = jnp.zeros((1, 32), jnp.int32)
        t2 = t1.at[:, 20:].set(5)
        o1 = model_mod.decoder_logits(params, cfg, t1)
        o2 = model_mod.decoder_logits(params, cfg, t2)
        assert_allclose(np.asarray(o1[:, :20]), np.asarray(o2[:, :20]), atol=2e-4)

    @pytest.mark.parametrize("attn", ["softmax", "hedgehog", "elu"])
    def test_decoder_causality(self, attn):
        cfg = tiny_decoder(attn)
        params = model_mod.init_params(jax.random.PRNGKey(2), cfg)
        t1 = jnp.ones((1, 32), jnp.int32)
        t2 = t1.at[:, 16:].set(7)
        o1 = model_mod.decoder_logits(params, cfg, t1)
        o2 = model_mod.decoder_logits(params, cfg, t2)
        assert_allclose(np.asarray(o1[:, :16]), np.asarray(o2[:, :16]), atol=2e-4)


class TestTraining:
    def test_adamw_matches_reference_update(self):
        """Hand-check one AdamW step on a scalar parameter."""
        p = {"w": jnp.array([1.0])}
        g = {"w": jnp.array([0.5])}
        m = {"w": jnp.array([0.0])}
        v = {"w": jnp.array([0.0])}
        new_p, new_m, new_v = train.adamw_update(p, g, m, v, step=1, lr=0.1, wd=0.0)
        # bias-corrected: mhat = g, vhat = g^2 -> update = lr * g/|g| = 0.1
        assert_allclose(float(new_p["w"][0]), 1.0 - 0.1, atol=1e-5)
        assert_allclose(float(new_m["w"][0]), 0.05, atol=1e-7)
        assert_allclose(float(new_v["w"][0]), 0.00025, atol=1e-9)

    def test_weight_decay_decoupled(self):
        p = {"w": jnp.array([2.0])}
        zero = {"w": jnp.array([0.0])}
        new_p, _, _ = train.adamw_update(p, zero, zero, zero, step=1, lr=0.1, wd=0.01)
        # zero grad -> pure decay: w - lr*wd*w
        assert_allclose(float(new_p["w"][0]), 2.0 * (1.0 - 0.1 * 0.01), atol=1e-6)

    @pytest.mark.parametrize("attn", ["softmax", "hedgehog"])
    def test_train_step_reduces_loss(self, attn):
        cfg = tiny_decoder(attn)
        step_fn = jax.jit(train.make_train_step(cfg))
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        m, v = train.adamw_init(params)
        step = jnp.array(0, jnp.int32)
        toks = jnp.tile(jnp.arange(32, dtype=jnp.int32)[None] % 7, (4, 1))
        tgts = jnp.roll(toks, -1, axis=1)
        mask = jnp.ones((4, 32))
        losses = []
        for _ in range(8):
            params, m, v, step, loss = step_fn(
                params, m, v, step, jnp.float32(1e-2), jnp.float32(0.0), toks, tgts, mask
            )
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_freeze_mask_paths(self):
        grads = {"blocks": [{"mix": {"fm": {"w": jnp.ones((2,))}, "wq": jnp.ones((2,))}}]}
        masked = train.mask_grads(grads, lambda p: "/fm/" not in f"/{p}/")
        assert float(masked["blocks"][0]["mix"]["fm"]["w"].sum()) == 2.0
        assert float(masked["blocks"][0]["mix"]["wq"].sum()) == 0.0


class TestDistillation:
    def test_distill_loss_decreases(self):
        cfg = tiny_encoder(attn="hedgehog")
        step_fn = jax.jit(distill.make_distill_step(cfg))
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        m, v = train.adamw_init(params)
        step = jnp.array(0, jnp.int32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 32)
        losses = []
        for _ in range(10):
            params, m, v, step, loss = step_fn(
                params, m, v, step, jnp.float32(1e-2), jnp.float32(0.0), toks
            )
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_distill_freezes_base_weights(self):
        cfg = tiny_encoder(attn="hedgehog")
        step_fn = jax.jit(distill.make_distill_step(cfg))
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        wq_before = np.asarray(params["blocks"][0]["mix"]["wq"]).copy()
        fm_before = np.asarray(params["blocks"][0]["mix"]["fm"]["w"]).copy()
        m, v = train.adamw_init(params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 32)
        params, *_ = step_fn(
            params, m, v, jnp.array(0, jnp.int32), jnp.float32(1e-2), jnp.float32(0.0), toks
        )
        assert_allclose(np.asarray(params["blocks"][0]["mix"]["wq"]), wq_before, atol=1e-7)
        assert np.abs(np.asarray(params["blocks"][0]["mix"]["fm"]["w"]) - fm_before).max() > 1e-6

    def test_kl_drops_with_distillation(self):
        cfg = tiny_encoder(attn="hedgehog")
        step_fn = jax.jit(distill.make_distill_step(cfg))
        eval_fn = jax.jit(distill.make_distill_eval(cfg))
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 32)
        _, kl0 = eval_fn(params, toks)
        m, v = train.adamw_init(params)
        step = jnp.array(0, jnp.int32)
        for _ in range(15):
            params, m, v, step, _ = step_fn(
                params, m, v, step, jnp.float32(1e-2), jnp.float32(0.0), toks
            )
        _, kl1 = eval_fn(params, toks)
        assert float(kl1) < float(kl0)


class TestDecodeParity:
    def test_recurrent_decode_matches_full_forward(self):
        """decode_step token-by-token == decoder_logits on the same prefix."""
        cfg = tiny_decoder(attn="hedgehog")
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, 32)
        full = model_mod.decoder_logits(params, cfg, toks)

        fn, dp = decode.make_decode_step(cfg)
        fn = jax.jit(fn)
        L, B, H, DV = cfg.n_layers, 1, cfg.heads, cfg.d_head
        s = jnp.zeros((L, B, H, dp, DV))
        z = jnp.zeros((L, B, H, dp))
        for t in range(12):
            logits, s, z = fn(
                params, toks[:, t], jnp.array([t], jnp.int32), s, z
            )
            assert_allclose(
                np.asarray(logits[0]), np.asarray(full[0, t]), rtol=2e-3, atol=2e-3
            )

    def test_softmax_kv_decode_matches_full_forward(self):
        cfg = tiny_decoder(attn="softmax")
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, 32)
        full = model_mod.decoder_logits(params, cfg, toks)
        fn = jax.jit(decode.make_decode_step_softmax(cfg, 16))
        L, B, H, DH = cfg.n_layers, 1, cfg.heads, cfg.d_head
        kc = jnp.zeros((L, B, H, 16, DH))
        vc = jnp.zeros((L, B, H, 16, DH))
        for t in range(10):
            logits, kc, vc = fn(params, toks[:, t], jnp.array([t], jnp.int32), kc, vc)
            assert_allclose(
                np.asarray(logits[0]), np.asarray(full[0, t]), rtol=2e-3, atol=2e-3
            )


class TestLora:
    def test_zero_lora_is_identity(self):
        cfg = tiny_decoder(attn="softmax")
        params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        adapters = lora.init_lora(jax.random.PRNGKey(1), cfg, rank=4)
        merged = lora.merge(params, adapters)
        toks = jnp.zeros((1, 32), jnp.int32)
        o1 = model_mod.decoder_logits(params, cfg, toks)
        o2 = model_mod.decoder_logits(merged, cfg, toks)
        assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)

    def test_lora_train_updates_adapters_only(self):
        cfg = tiny_decoder(attn="softmax")
        step_fn = jax.jit(lora.make_lora_train_step(cfg, alpha=16.0, rank=4))
        base = model_mod.init_params(jax.random.PRNGKey(0), cfg)
        ad = lora.init_lora(jax.random.PRNGKey(1), cfg, rank=4)
        m, v = train.adamw_init(ad)
        toks = jnp.ones((2, 32), jnp.int32)
        tgts = jnp.roll(toks, -1, 1)
        mask = jnp.ones((2, 32))
        ad2, m, v, step, loss = step_fn(
            base, ad, m, v, jnp.array(0, jnp.int32), jnp.float32(1e-2),
            jnp.float32(0.0), toks, tgts, mask
        )
        # b matrices move away from zero
        delta = np.abs(np.asarray(ad2[0]["wq"]["b"])).max()
        assert delta > 0.0
        assert np.isfinite(float(loss))


class TestConfigs:
    def test_all_families_well_formed(self):
        for name, (cfg, spec) in configs.FAMILIES.items():
            assert cfg.name == name
            assert spec.batch_size > 0 and spec.seq_len > 0
            if cfg.kind != "vit":
                assert cfg.vocab >= 4

    def test_glue_task_table(self):
        assert configs.GLUE_TASKS["mnli"] == (3, False)
        assert configs.GLUE_TASKS["stsb"] == (1, True)
        assert len(configs.GLUE_TASKS) == 8
