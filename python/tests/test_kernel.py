"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The core correctness signal of the whole stack: if these pass, every HLO
artifact built from the kernels computes the paper's math.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import feature_maps, ref
from compile.kernels.linear_attention import (
    linear_attention_decode_step,
    linear_attention_pallas,
    linear_attention_scan,
)
from compile.kernels.softmax_attention import softmax_attention_pallas


def make_qkv(seed, b, h, n, d, dv, positive=False):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, h, n, d), jnp.float32)
    k = jax.random.normal(k2, (b, h, n, d), jnp.float32)
    v = jax.random.normal(k3, (b, h, n, dv), jnp.float32)
    if positive:
        q = jnp.abs(q) + 0.05
        k = jnp.abs(k) + 0.05
    return q, k, v


# ---------------------------------------------------------------------------
# Chunked linear attention kernel
# ---------------------------------------------------------------------------

class TestLinearAttentionPallas:
    @pytest.mark.parametrize("chunk", [16, 32, 64])
    def test_matches_quadratic_oracle(self, chunk):
        qf, kf, v = make_qkv(0, 2, 3, 128, 16, 16, positive=True)
        got = linear_attention_pallas(qf, kf, v, chunk)
        want = ref.linear_attention(qf, kf, v, causal=True)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_matches_recurrent_oracle(self):
        qf, kf, v = make_qkv(1, 1, 2, 64, 8, 8, positive=True)
        got = linear_attention_pallas(qf, kf, v, 16)
        want = ref.linear_attention_recurrent(qf, kf, v)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_scan_form_matches_pallas(self):
        qf, kf, v = make_qkv(2, 2, 2, 96, 12, 12, positive=True)
        a = linear_attention_pallas(qf, kf, v, 32)
        b = linear_attention_scan(qf, kf, v, 32)
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)

    def test_causality(self):
        """Output at position i must not depend on tokens > i."""
        qf, kf, v = make_qkv(3, 1, 1, 64, 8, 8, positive=True)
        base = linear_attention_pallas(qf, kf, v, 16)
        # Perturb the last 16 tokens of k/v; first 48 outputs must not move.
        kf2 = kf.at[..., 48:, :].set(kf[..., 48:, :] * 3.0 + 1.0)
        v2 = v.at[..., 48:, :].set(-v[..., 48:, :])
        out2 = linear_attention_pallas(qf, kf2, v2, 16)
        assert_allclose(
            np.asarray(base[..., :48, :]), np.asarray(out2[..., :48, :]), atol=1e-6
        )

    def test_rows_are_convex_combinations(self):
        """With positive features, y_i lies in the convex hull of v_{<=i}."""
        qf, kf, v = make_qkv(4, 1, 1, 32, 8, 4, positive=True)
        out = np.asarray(linear_attention_pallas(qf, kf, v, 16))
        v_np = np.asarray(v)
        for i in range(32):
            lo = v_np[0, 0, : i + 1].min(axis=0) - 1e-4
            hi = v_np[0, 0, : i + 1].max(axis=0) + 1e-4
            assert (out[0, 0, i] >= lo).all() and (out[0, 0, i] <= hi).all()

    def test_custom_vjp_matches_autodiff_oracle(self):
        qf, kf, v = make_qkv(5, 1, 2, 64, 8, 8, positive=True)

        def f_pal(qf, kf, v):
            return (linear_attention_pallas(qf, kf, v, 16) ** 2).sum()

        def f_ref(qf, kf, v):
            return (ref.linear_attention(qf, kf, v, causal=True) ** 2).sum()

        gp = jax.grad(f_pal, argnums=(0, 1, 2))(qf, kf, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(qf, kf, v)
        for a, b in zip(gp, gr):
            assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    def test_vjp_randomized_cotangent(self):
        qf, kf, v = make_qkv(6, 1, 1, 32, 8, 8, positive=True)
        dy = jax.random.normal(jax.random.PRNGKey(9), (1, 1, 32, 8))

        _, vjp_p = jax.vjp(lambda a, b, c: linear_attention_pallas(a, b, c, 16), qf, kf, v)
        _, vjp_r = jax.vjp(
            lambda a, b, c: ref.linear_attention(a, b, c, causal=True), qf, kf, v
        )
        for a, b in zip(vjp_p(dy), vjp_r(dy)):
            assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        b=st.integers(1, 2),
        h=st.integers(1, 3),
        nc=st.integers(1, 4),
        d=st.sampled_from([4, 8, 16]),
        dv=st.sampled_from([4, 8, 16]),
        chunk=st.sampled_from([8, 16]),
    )
    def test_hypothesis_shape_sweep(self, seed, b, h, nc, d, dv, chunk):
        n = nc * chunk
        qf, kf, v = make_qkv(seed, b, h, n, d, dv, positive=True)
        got = linear_attention_pallas(qf, kf, v, chunk)
        want = ref.linear_attention(qf, kf, v, causal=True)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-5, atol=5e-5)


class TestDecodeStep:
    def test_decode_matches_prefill(self):
        """Running the recurrent decode step token-by-token equals prefill."""
        qf, kf, v = make_qkv(7, 2, 2, 24, 8, 8, positive=True)
        want = np.asarray(ref.linear_attention(qf, kf, v, causal=True))
        b, h, n, dp = qf.shape
        dv = v.shape[-1]
        s = jnp.zeros((b, h, dp, dv))
        z = jnp.zeros((b, h, dp))
        for t in range(n):
            s, z, y = linear_attention_decode_step(
                s, z, qf[..., t, :], kf[..., t, :], v[..., t, :]
            )
            assert_allclose(np.asarray(y), want[..., t, :], rtol=2e-5, atol=2e-5)

    def test_state_shapes_preserved(self):
        s = jnp.zeros((1, 2, 8, 4))
        z = jnp.zeros((1, 2, 8))
        qt = jnp.ones((1, 2, 8))
        s2, z2, y = linear_attention_decode_step(s, z, qt, qt, jnp.ones((1, 2, 4)))
        assert s2.shape == s.shape and z2.shape == z.shape and y.shape == (1, 2, 4)


# ---------------------------------------------------------------------------
# Flash softmax kernel
# ---------------------------------------------------------------------------

class TestSoftmaxAttentionPallas:
    @pytest.mark.parametrize("chunk,n", [(16, 64), (32, 128), (64, 128)])
    def test_matches_oracle(self, chunk, n):
        q, k, v = make_qkv(10, 2, 2, n, 16, 16)
        got = softmax_attention_pallas(q, k, v, chunk)
        want = ref.softmax_attention(q, k, v, causal=True)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_causality(self):
        q, k, v = make_qkv(11, 1, 1, 64, 8, 8)
        base = softmax_attention_pallas(q, k, v, 16)
        k2 = k.at[..., 32:, :].add(5.0)
        v2 = v.at[..., 32:, :].multiply(-2.0)
        out2 = softmax_attention_pallas(q, k2, v2, 16)
        assert_allclose(
            np.asarray(base[..., :32, :]), np.asarray(out2[..., :32, :]), atol=1e-6
        )

    def test_large_scores_stable(self):
        """Online-softmax must survive large logits (no overflow)."""
        q, k, v = make_qkv(12, 1, 1, 32, 8, 8)
        got = softmax_attention_pallas(q * 30.0, k * 30.0, v, 16)
        want = ref.softmax_attention(q * 30.0, k * 30.0, v, causal=True)
        assert np.isfinite(np.asarray(got)).all()
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16), nc=st.integers(1, 4), d=st.sampled_from([4, 8, 16]))
    def test_hypothesis_sweep(self, seed, nc, d):
        n = nc * 16
        q, k, v = make_qkv(seed, 1, 2, n, d, d)
        got = softmax_attention_pallas(q, k, v, 16)
        want = ref.softmax_attention(q, k, v, causal=True)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# Feature maps
# ---------------------------------------------------------------------------

class TestFeatureMaps:
    def test_registry_complete(self):
        for name in ["elu", "relu", "exp_t1", "exp_t2", "performer", "cosformer",
                     "taylor", "hedgehog", "hedgehog_sm", "t2r"]:
            assert name in feature_maps.REGISTRY

    @pytest.mark.parametrize("name", feature_maps.ALL_LINEAR)
    def test_feature_dims(self, name):
        fm = feature_maps.get(name)
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8, 8))
        params = fm.init(jax.random.PRNGKey(1), 3, 8)
        out = fm.apply(params, x)
        assert out.shape == (2, 3, 8, fm.feature_dim(8))

    @pytest.mark.parametrize("name", ["elu", "exp_t1", "exp_t2", "performer",
                                      "hedgehog", "hedgehog_sm", "taylor"])
    def test_positive_attention_weights(self, name):
        """Positivity: the resulting attention weights are >= 0 (Sec 2)."""
        fm = feature_maps.get(name)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 16, 8))
        params = fm.init(jax.random.PRNGKey(3), 2, 8)
        f = fm.apply(params, x)
        attn = ref.linear_attention_weights(f, f, causal=True)
        assert (np.asarray(attn) >= -1e-6).all()

    def test_taylor_approximates_exp(self):
        """phi_taylor(q).phi_taylor(k) == 1 + q.k + (q.k)^2/2 exactly."""
        q = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 8, 6)) * 0.5
        k = jax.random.normal(jax.random.PRNGKey(5), (1, 1, 8, 6)) * 0.5
        fq, fk = ref.feature_taylor(q), ref.feature_taylor(k)
        got = jnp.einsum("bhnp,bhmp->bhnm", fq, fk)
        qk = jnp.einsum("bhnd,bhmd->bhnm", q, k)
        want = 1.0 + qk + 0.5 * qk**2
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_hedgehog_identity_init(self):
        """Identity-initialized Hedgehog == [exp(x), exp(-x)] (A.2)."""
        x = jax.random.normal(jax.random.PRNGKey(6), (1, 2, 4, 8))
        params = feature_maps.init_params("hedgehog", jax.random.PRNGKey(7), 2, 8)
        got = feature_maps.apply("hedgehog", params, x)
        want = jnp.concatenate([jnp.exp(x), jnp.exp(-x)], axis=-1)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_hedgehog_sm_normalized(self):
        """Eq. 5 variant: each half sums to 1 over the feature dim."""
        x = jax.random.normal(jax.random.PRNGKey(8), (1, 2, 4, 8)) * 3
        params = feature_maps.init_params("hedgehog_sm", jax.random.PRNGKey(9), 2, 8)
        out = feature_maps.apply("hedgehog_sm", params, x)
        pos, neg = out[..., :8], out[..., 8:]
        assert_allclose(np.asarray(pos.sum(-1)), 1.0, rtol=1e-5)
        assert_allclose(np.asarray(neg.sum(-1)), 1.0, rtol=1e-5)

    def test_performer_unbiasedness_direction(self):
        """E[phi(q).phi(k)] ~ exp(q.k) for FAVOR+ with many features."""
        d = 4
        q = jnp.ones((1, 1, 1, d)) * 0.3
        k = jnp.ones((1, 1, 1, d)) * 0.2
        proj = jax.random.normal(jax.random.PRNGKey(10), (d, 4096))
        fq = ref.feature_performer(q, proj)
        fk = ref.feature_performer(k, proj)
        got = float(jnp.einsum("bhnp,bhmp->bhnm", fq, fk)[0, 0, 0, 0])
        want = float(jnp.exp((q * k).sum()))
        assert abs(got - want) / want < 0.15

    def test_cosformer_locality(self):
        """cosFormer upweights nearby positions: same-vector similarity decays
        with distance for the cos component."""
        n, d = 32, 8
        x = jnp.abs(jax.random.normal(jax.random.PRNGKey(11), (1, 1, n, d)))
        f = ref.feature_cosformer(x)
        # similarity of token 16's q-feature with each k-feature of same x
        sims = np.asarray(jnp.einsum("p,mp->m", f[0, 0, 16], f[0, 0]))
        raw = np.asarray(jnp.einsum("d,md->m", x[0, 0, 16], x[0, 0]))
        # relative weight vs raw dot product decays with |i-16|
        rel = sims / (raw + 1e-6)
        assert rel[16] > rel[0] and rel[16] > rel[31]


# ---------------------------------------------------------------------------
# Distillation loss + analysis references
# ---------------------------------------------------------------------------

class TestDistillAndAnalysis:
    def test_distill_loss_minimized_at_match(self):
        """Soft-XE is minimized (== teacher entropy) when student == teacher."""
        q, k, _ = make_qkv(13, 1, 2, 16, 8, 8)
        true = ref.softmax_attention_weights(q, k, causal=True)
        loss_match = ref.distill_soft_xe(true, true)
        uniform = ref.linear_attention_weights(
            jnp.ones_like(q), jnp.ones_like(k), causal=True
        )
        loss_uniform = ref.distill_soft_xe(uniform, true)
        assert float(loss_match) < float(loss_uniform)

    def test_entropy_bounds(self):
        n = 16
        # one-hot rows -> entropy 0; uniform rows -> log(n)
        eye = jnp.eye(n)[None, None]
        assert float(ref.attention_entropy(eye)) < 1e-4
        unif = jnp.full((1, 1, n, n), 1.0 / n)
        assert abs(float(ref.attention_entropy(unif)) - np.log(n)) < 1e-3

    def test_spiky_maps_have_lower_entropy(self):
        """The paper's Fig 2 claim, in miniature: exp_t2 features give lower
        attention entropy than 1+ELU on the same q/k."""
        q, k, _ = make_qkv(14, 2, 4, 64, 16, 16)
        f_elu = ref.feature_elu
        h_elu = ref.attention_entropy(
            ref.linear_attention_weights(f_elu(q), f_elu(k), causal=True)
        )
        f_exp = lambda x: ref.feature_exp_t(x, 2.0)
        h_exp = ref.attention_entropy(
            ref.linear_attention_weights(f_exp(q), f_exp(k), causal=True)
        )
        assert float(h_exp) < float(h_elu)

    def test_kl_zero_iff_equal(self):
        q, k, _ = make_qkv(15, 1, 1, 16, 8, 8)
        p = ref.softmax_attention_weights(q, k, causal=True)
        assert abs(float(ref.attention_kl(p, p))) < 1e-5
        q2 = q + 1.0
        p2 = ref.softmax_attention_weights(q2, k, causal=True)
        assert float(ref.attention_kl(p, p2)) > 1e-3

    def test_monotonicity_property(self):
        """Taylor features are monotone in q.k in the bounded regime the
        paper identifies (q.k >= -1: d/dx [1+x+x^2/2] = 1+x). Checks the
        Fig 3/5 diagnostic computation."""
        d = 8
        k1 = jax.random.normal(jax.random.PRNGKey(16), (d,))
        nrm = float((k1 * k1).sum())
        # scales chosen so q.k spans [-0.9, +2.0] * — inside the bounded regime
        scales = jnp.linspace(-0.9 / nrm, 2.0 / nrm, 21)
        q = scales[:, None] * k1[None, :]  # dot products increase along rows
        qb = q[None, None]  # (1,1,21,d)
        kb = k1[None, None, None, :]
        fq = ref.feature_taylor(qb)
        fk = ref.feature_taylor(kb)
        sims = np.asarray(jnp.einsum("bhnp,bhmp->bhnm", fq, fk))[0, 0, :, 0]
        assert (np.diff(sims) > -1e-5).all()
